//! The run ledger: one JSONL file per run (`--ledger-out`).
//!
//! Each line is one self-contained JSON object with a `"record"`
//! discriminator, written in a fixed deterministic order:
//!
//! 1. `"provenance"` — resolved config: mode, solver, kernel backend,
//!    resolved fold strategy + its source, thread/task counts, grid
//!    shape, seed, the full trust/recovery knob set, and the headline
//!    result (λ*, score, wall).
//! 2. `"degradation"` — one line per recovery-ladder climb, in the
//!    deterministic report order.
//! 3. `"certification"` — the ALOOCV-vs-LOO verdict, when `--certify`
//!    ran.
//! 4. `"phase"` — one line per `PhaseTimer` phase: invocation count,
//!    total seconds, and p50/p90/p99 µs from the latency histograms.
//! 5. `"task_kind"` — one line per event kind with its span quantiles.
//! 6. `"summary"` — event totals and the ring-drop counter.
//!
//! The file is written via temp + atomic rename like every other
//! artifact, so readers never observe a torn ledger. Non-finite floats
//! (e.g. an `inf` trust budget) serialize as `null` to keep every line
//! standard JSON — `ci.sh --obs` parses the ledger line-by-line with
//! `python3 -m json.tool` semantics.

use crate::cv::aloocv::Certification;
use crate::cv::recovery::{Degradation, RecoveryPolicy};
use crate::obs::hist::Hist;
use crate::obs::ObsReport;
use crate::util::PhaseTimer;

/// Everything one ledger needs, borrowed from the finished report.
pub struct LedgerRun<'a> {
    /// `"kfold"`, `"loo"`, or `"aloocv"`.
    pub mode: &'a str,
    /// Solver name as given on the CLI (k-fold only; `"chol"` for the
    /// LOO/ALOOCV tiers, which are factor-level by construction).
    pub solver: &'a str,
    pub kernel_backend: &'a str,
    pub fold_strategy: &'a str,
    pub strategy_source: &'a str,
    pub threads: usize,
    pub tasks: usize,
    pub k_folds: usize,
    pub q_grid: usize,
    pub g_samples: usize,
    pub seed: u64,
    pub policy: &'a RecoveryPolicy,
    pub best_lambda: f64,
    pub best_error: f64,
    pub wall_secs: f64,
    pub degradations: &'a [Degradation],
    pub certification: Option<&'a Certification>,
    pub timer: &'a PhaseTimer,
    pub obs: &'a ObsReport,
}

/// JSON string escaping for free-form detail text.
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Finite floats as JSON numbers; NaN/±inf as `null` (JSON has no
/// non-finite literals). Uses the shortest round-trip exponential form
/// (`{v:e}`, e.g. `3.0000000000000004e-1`): a fixed-precision format
/// would truncate provenance — λ grid endpoints, trust budgets, RMSE
/// values — so the ledger could no longer reproduce the run exactly.
fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

/// Optional µs quantile: `null` for an empty histogram.
fn jq(h: &Hist, q: f64) -> String {
    match h.quantile_us(q) {
        Some(us) => format!("{us:.3}"),
        None => "null".to_string(),
    }
}

/// Render the full ledger as JSONL (exposed for tests; `write_ledger`
/// is the file-writing entry point).
pub fn render_ledger(run: &LedgerRun) -> String {
    let mut s = String::new();

    // 1. provenance
    s.push_str(&format!(
        "{{\"record\":\"provenance\",\"mode\":\"{}\",\"solver\":\"{}\",\
         \"kernel_backend\":\"{}\",\"fold_strategy\":\"{}\",\
         \"strategy_source\":\"{}\",\"threads\":{},\"tasks\":{},\
         \"k_folds\":{},\"q_grid\":{},\"g_samples\":{},\"seed\":{},\
         \"trust\":{{\"max_relative_drift\":{},\"max_hops\":{},\
         \"max_shift_retries\":{},\"shift_growth\":{},\"task_retries\":{}}},\
         \"best_lambda\":{},\"best_error\":{},\"wall_secs\":{}}}\n",
        escape_json(run.mode),
        escape_json(run.solver),
        escape_json(run.kernel_backend),
        escape_json(run.fold_strategy),
        escape_json(run.strategy_source),
        run.threads,
        run.tasks,
        run.k_folds,
        run.q_grid,
        run.g_samples,
        run.seed,
        jf(run.policy.budget.max_relative_drift),
        run.policy.budget.max_hops,
        run.policy.max_shift_retries,
        jf(run.policy.shift_growth),
        run.policy.task_retries,
        jf(run.best_lambda),
        jf(run.best_error),
        jf(run.wall_secs),
    ));

    // 2. degradations, in report (deterministic) order
    for d in run.degradations {
        s.push_str(&format!(
            "{{\"record\":\"degradation\",\"surface\":\"{}\",\"fold\":{},\
             \"lambda\":{},\"cause\":\"{}\",\"rung\":\"{}\",\"trust\":{},\
             \"detail\":\"{}\"}}\n",
            escape_json(d.surface),
            d.fold,
            jf(d.lambda),
            escape_json(d.cause),
            d.rung.name(),
            jf(d.trust),
            escape_json(&d.detail),
        ));
    }

    // 3. certification verdict
    if let Some(c) = run.certification {
        s.push_str(&format!(
            "{{\"record\":\"certification\",\"aloo_lambda\":{},\
             \"loo_lambda\":{},\"decades\":{},\"certified\":{}}}\n",
            jf(c.aloo_lambda),
            jf(c.loo_lambda),
            jf(c.decades),
            c.certified,
        ));
    }

    // 4. per-phase latency summaries: counts/totals from the timer,
    // quantiles from the histograms (sorted phase-name order)
    let mut phases: Vec<&str> = run.timer.entries().iter().map(|(n, _)| n.as_str()).collect();
    phases.sort_unstable();
    for name in phases {
        let total: f64 = run
            .timer
            .entries()
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, secs)| *secs)
            .unwrap_or(0.0);
        let empty = Hist::new();
        let h = run.obs.phase_hists.get(name).unwrap_or(&empty);
        s.push_str(&format!(
            "{{\"record\":\"phase\",\"name\":\"{}\",\"count\":{},\
             \"total_secs\":{},\"p50_us\":{},\"p90_us\":{},\"p99_us\":{}}}\n",
            escape_json(name),
            run.timer.count(name),
            jf(total),
            jq(h, 0.50),
            jq(h, 0.90),
            jq(h, 0.99),
        ));
    }

    // 5. per-task-kind span summaries
    for (name, h) in run.obs.kind_hists.entries() {
        s.push_str(&format!(
            "{{\"record\":\"task_kind\",\"name\":\"{}\",\"count\":{},\
             \"p50_us\":{},\"p90_us\":{},\"p99_us\":{}}}\n",
            escape_json(name),
            h.count(),
            jq(h, 0.50),
            jq(h, 0.90),
            jq(h, 0.99),
        ));
    }

    // 6. totals
    s.push_str(&format!(
        "{{\"record\":\"summary\",\"events\":{},\"dropped\":{}}}\n",
        run.obs.events.len(),
        run.obs.dropped,
    ));
    s
}

/// Write the ledger to `path` (temp file + atomic rename).
pub fn write_ledger(path: &str, run: &LedgerRun) -> crate::Result<()> {
    super::write_atomic(path, &render_ledger(run))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cv::recovery::Rung;
    use crate::obs::trace::{Event, Outcome};

    fn sample_run<'a>(
        policy: &'a RecoveryPolicy,
        degs: &'a [Degradation],
        timer: &'a PhaseTimer,
        obs: &'a ObsReport,
    ) -> LedgerRun<'a> {
        LedgerRun {
            mode: "kfold",
            solver: "chol",
            kernel_backend: "scalar",
            fold_strategy: "downdate",
            strategy_source: "default",
            threads: 2,
            tasks: 7,
            k_folds: 3,
            q_grid: 8,
            g_samples: 4,
            seed: 42,
            policy,
            best_lambda: 0.1,
            best_error: 0.5,
            wall_secs: 0.01,
            degradations: degs,
            certification: None,
            timer,
            obs,
        }
    }

    #[test]
    fn every_line_is_json_and_required_records_present() {
        let policy = RecoveryPolicy::default();
        let degs = vec![Degradation {
            surface: "grid",
            fold: 1,
            lambda: 0.5,
            cause: "breakdown",
            rung: Rung::ShiftedRefactor,
            trust: 0.0,
            detail: "pivot −1e-3 at \"row\" 7\nretry".to_string(),
        }];
        let mut timer = PhaseTimer::default();
        timer.time("factor", || std::hint::black_box(1 + 1));
        let mut obs = ObsReport::default();
        obs.phase_hists.record("factor", 1500);
        obs.kind_hists.record("grid", 2500);
        obs.events.push(Event {
            kind: "grid",
            outcome: Outcome::Degraded,
            ..Event::default()
        });
        let s = render_ledger(&sample_run(&policy, &degs, &timer, &obs));

        for line in s.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'), "line: {line}");
            // no raw control characters or invalid JSON literals survive
            assert!(!line.contains('\t'));
            assert!(!line.contains("inf") || line.contains("null"), "line: {line}");
        }
        assert!(s.contains("\"record\":\"provenance\""));
        assert!(s.contains("\"strategy_source\":\"default\""));
        assert!(s.contains("\"record\":\"degradation\""));
        assert!(s.contains("\\\"row\\\" 7\\nretry"));
        assert!(s.contains("\"record\":\"phase\""));
        assert!(s.contains("\"p99_us\""));
        assert!(s.contains("\"record\":\"task_kind\""));
        assert!(s.contains("\"record\":\"summary\""));
    }

    /// The parse-back pin for the full-precision float format: every value
    /// `jf` emits must parse back to the identical f64 bits, including the
    /// ones a 7-significant-digit format destroys (0.1 + 0.2, subnormals,
    /// one-ulp neighbours of 1.0).
    #[test]
    fn ledger_floats_parse_back_bitwise() {
        for &v in &[
            0.0,
            -0.0,
            0.1,
            0.1 + 0.2,
            1.0 / 3.0,
            std::f64::consts::PI,
            6.022_140_76e23,
            -1.234_567_890_123_456_7e-89,
            5e-324,              // smallest subnormal
            f64::MIN_POSITIVE,
            f64::MAX,
            1.0 + f64::EPSILON, // one ulp above 1.0
        ] {
            let s = jf(v);
            let back: f64 = s.parse().expect("jf output must be a parseable number");
            assert_eq!(back.to_bits(), v.to_bits(), "{v:?} rendered as {s}");
        }
        // and through a fully rendered provenance line: extract the
        // best_lambda field and round-trip it bitwise
        let policy = RecoveryPolicy::default();
        let timer = PhaseTimer::default();
        let obs = ObsReport::default();
        let mut run = sample_run(&policy, &[], &timer, &obs);
        run.best_lambda = 0.1 + 0.2; // 0.30000000000000004 — dies at 7 digits
        let s = render_ledger(&run);
        let line = s.lines().next().unwrap();
        let tag = "\"best_lambda\":";
        let at = line.find(tag).unwrap() + tag.len();
        let num: String = line[at..]
            .chars()
            .take_while(|c| !matches!(c, ',' | '}'))
            .collect();
        assert_eq!(
            num.parse::<f64>().unwrap().to_bits(),
            (0.1f64 + 0.2).to_bits(),
            "best_lambda must survive the ledger round trip bitwise: {num}"
        );
    }

    #[test]
    fn non_finite_trust_budget_serializes_as_null() {
        let mut policy = RecoveryPolicy::default();
        policy.budget.max_relative_drift = f64::INFINITY;
        let timer = PhaseTimer::default();
        let obs = ObsReport::default();
        let s = render_ledger(&sample_run(&policy, &[], &timer, &obs));
        assert!(s.contains("\"max_relative_drift\":null"));
    }

    #[test]
    fn escape_json_handles_specials() {
        assert_eq!(escape_json("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape_json("\u{1}"), "\\u0001");
    }
}
