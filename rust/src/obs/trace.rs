//! Per-worker lock-free event rings and the post-run deterministic merge.
//!
//! Every observed span (one pool task, one coordinator stage) becomes one
//! fixed-size [`Event`]. While a run is armed, each pool worker writes
//! its events into its own single-producer ring (plus one ring for the
//! coordinating thread), so the hot path takes no lock and performs no
//! allocation: rings are pre-sized at arm time from the sweep plan's
//! shape. After the run quiesces, [`RunObs::collect`] drains every ring
//! on the coordinating thread and sorts by `(task_id, attempt)`.
//!
//! **Ordering contract.** `task_id`s are allocated on the coordinating
//! thread at job-construction time, in the engine's deterministic
//! construction order — so the merged sequence of
//! `(task_id, attempt, kind, outcome)` tuples is bit-identical at any
//! worker count. Wall-clock fields (`start_us`, `stop_us`) and the
//! `worker` id are *payload*: they vary run to run and worker count to
//! worker count; the ordering and content of everything else is the
//! contract, pinned by `tests/obs.rs`.
//!
//! Ring overflow never blocks and never reorders: an over-capacity push
//! is counted in `dropped` and surfaced in the report (the engine sizes
//! rings so this does not happen in practice).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::pool::worker_index;
use crate::cv::recovery::Rung;

/// How an observed span ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Completed without touching the recovery ladder.
    Ok,
    /// Completed, but one or more cells climbed a recovery rung.
    Degraded,
    /// The task was quarantined after exhausting its retry budget.
    Quarantined,
}

impl Outcome {
    pub fn name(&self) -> &'static str {
        match self {
            Outcome::Ok => "ok",
            Outcome::Degraded => "degraded",
            Outcome::Quarantined => "quarantined",
        }
    }
}

impl Default for Outcome {
    fn default() -> Self {
        Outcome::Ok
    }
}

/// One observed span. `Copy` and fixed-size so ring slots never allocate.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Deterministic id, allocated at job-construction time on the
    /// coordinating thread — the primary sort key of the merged log.
    pub task_id: u32,
    /// 0-based attempt (retried tasks re-run with the next attempt).
    pub attempt: u32,
    /// Task kind: `"gram"`, `"prep"`, `"factor"`, `"chol"`,
    /// `"fold_downdate"`, `"grid"`, `"fold_sweep"`, `"loo_batch"`,
    /// `"aloo_batch"`, `"solve"`, `"fit"`, `"interp"`.
    pub kind: &'static str,
    /// Which wave/context scheduled it (`"gram"`, `"fold"`, `"anchor"`,
    /// `"exact"`, `"anchored"`, `"interp"`, `"loo"`, `"aloocv"`,
    /// `"curve"`, `"task"`).
    pub surface: &'static str,
    /// Fold index, or the batch start row for LOO/ALOOCV batches; −1
    /// when not fold-addressed.
    pub fold: i64,
    /// λ/anchor/grid-cell index; −1 when not λ-addressed.
    pub lambda_index: i64,
    /// Ring index the event was written from (payload, not contract).
    pub worker: u32,
    /// Span start, µs since the run epoch (payload, not contract).
    pub start_us: u64,
    /// Span end, µs since the run epoch (payload, not contract).
    pub stop_us: u64,
    pub outcome: Outcome,
    /// Highest recovery rung climbed inside the span, if any.
    pub rung: Option<Rung>,
    /// Number of degradations recorded inside the span.
    pub degradations: u32,
}

impl Default for Event {
    fn default() -> Self {
        Event {
            task_id: 0,
            attempt: 0,
            kind: "",
            surface: "",
            fold: -1,
            lambda_index: -1,
            worker: 0,
            start_us: 0,
            stop_us: 0,
            outcome: Outcome::Ok,
            rung: None,
            degradations: 0,
        }
    }
}

/// Single-producer event ring: one owner thread pushes, the coordinating
/// thread drains strictly after the run quiesces (pool waves are joined
/// before `collect` runs, so the Release/Acquire pair on `len` is the
/// only synchronization needed).
pub struct EventRing {
    slots: Vec<UnsafeCell<Event>>,
    len: AtomicUsize,
    dropped: AtomicUsize,
}

// SAFETY: slot `i` is written exactly once, by the single producing
// thread, strictly before `len` is stored (Release) with a value > i;
// readers only touch slots below the Acquire-loaded `len` and only
// after the producer has quiesced (the pool wave has joined).
unsafe impl Send for EventRing {}
unsafe impl Sync for EventRing {}

impl EventRing {
    pub fn with_capacity(cap: usize) -> EventRing {
        let mut slots = Vec::with_capacity(cap);
        for _ in 0..cap {
            slots.push(UnsafeCell::new(Event::default()));
        }
        EventRing {
            slots,
            len: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
        }
    }

    /// Push from the single producing thread. Never blocks; counts a
    /// drop when the ring is full.
    pub fn push(&self, ev: Event) {
        let i = self.len.load(Ordering::Relaxed);
        if i >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        unsafe {
            *self.slots[i].get() = ev;
        }
        self.len.store(i + 1, Ordering::Release);
    }

    fn drain_into(&self, out: &mut Vec<Event>) {
        let n = self.len.load(Ordering::Acquire);
        for slot in &self.slots[..n] {
            out.push(unsafe { *slot.get() });
        }
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed) as u64
    }
}

/// Per-run observation state: one ring per pool worker plus one for the
/// coordinating thread (the last ring). Armed by the engine only when
/// the run requests observability; when disarmed the hot path carries a
/// `None` and does nothing.
pub struct RunObs {
    rings: Vec<Arc<EventRing>>,
    epoch: Instant,
    next_id: AtomicU32,
}

impl RunObs {
    /// `workers` pool worker rings + one coordinator ring, each holding
    /// up to `capacity` events.
    pub fn new(workers: usize, capacity: usize) -> Arc<RunObs> {
        let rings = (0..workers + 1)
            .map(|_| Arc::new(EventRing::with_capacity(capacity)))
            .collect();
        Arc::new(RunObs {
            rings,
            epoch: Instant::now(),
            next_id: AtomicU32::new(0),
        })
    }

    /// Allocate the next task id. Called on the coordinating thread at
    /// job-construction time so ids follow deterministic construction
    /// order, independent of scheduling.
    pub fn alloc_id(&self) -> u32 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Microseconds since the run epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record `ev` into the calling thread's ring, stamping the ring
    /// index into `ev.worker`. Pool workers resolve their own ring via
    /// thread-local index; any other thread (the coordinator) uses the
    /// last ring.
    pub fn record(&self, mut ev: Event) {
        let last = self.rings.len() - 1;
        let i = worker_index().unwrap_or(last).min(last);
        ev.worker = i as u32;
        self.rings[i].push(ev);
    }

    /// Drain every ring and sort by `(task_id, attempt)` — the
    /// deterministic merge. Returns the events and the total number of
    /// dropped (over-capacity) events. Call only after all waves have
    /// quiesced.
    pub fn collect(&self) -> (Vec<Event>, u64) {
        let mut out = Vec::new();
        for r in &self.rings {
            r.drain_into(&mut out);
        }
        out.sort_by_key(|e| (e.task_id, e.attempt));
        let dropped = self.rings.iter().map(|r| r.dropped()).sum();
        (out, dropped)
    }
}

/// Export the merged event log as a Chrome trace-event JSON array
/// (load in `chrome://tracing` or <https://ui.perfetto.dev>): one
/// complete (`"ph":"X"`) event per span, `tid` = ring index so each
/// worker gets its own track.
pub fn write_chrome_trace(path: &str, events: &[Event]) -> crate::Result<()> {
    let mut s = String::with_capacity(events.len() * 160 + 16);
    s.push_str("[\n");
    for (i, e) in events.iter().enumerate() {
        let dur = e.stop_us.saturating_sub(e.start_us).max(1);
        s.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":1,\"tid\":{},\"args\":{{\"task_id\":{},\"attempt\":{},\
             \"outcome\":\"{}\",\"rung\":\"{}\",\"fold\":{},\"lambda_index\":{}}}}}{}\n",
            e.kind,
            e.surface,
            e.start_us,
            dur,
            e.worker,
            e.task_id,
            e.attempt,
            e.outcome.name(),
            e.rung.map(|r| r.name()).unwrap_or("none"),
            e.fold,
            e.lambda_index,
            if i + 1 < events.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    super::write_atomic(path, &s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(task_id: u32, attempt: u32) -> Event {
        Event {
            task_id,
            attempt,
            kind: "grid",
            surface: "interp",
            ..Event::default()
        }
    }

    #[test]
    fn collect_sorts_by_task_id_then_attempt() {
        let obs = RunObs::new(2, 16);
        // out-of-order pushes from the "coordinator" ring
        obs.record(ev(3, 0));
        obs.record(ev(1, 1));
        obs.record(ev(1, 0));
        obs.record(ev(0, 0));
        let (events, dropped) = obs.collect();
        let ids: Vec<(u32, u32)> = events.iter().map(|e| (e.task_id, e.attempt)).collect();
        assert_eq!(ids, [(0, 0), (1, 0), (1, 1), (3, 0)]);
        assert_eq!(dropped, 0);
    }

    #[test]
    fn overflow_drops_and_counts_without_blocking() {
        let ring = EventRing::with_capacity(2);
        ring.push(ev(0, 0));
        ring.push(ev(1, 0));
        ring.push(ev(2, 0));
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(ring.dropped(), 1);
    }

    #[test]
    fn alloc_id_is_sequential() {
        let obs = RunObs::new(1, 4);
        assert_eq!(obs.alloc_id(), 0);
        assert_eq!(obs.alloc_id(), 1);
        assert_eq!(obs.alloc_id(), 2);
    }

    #[test]
    fn chrome_trace_is_valid_json_shape() {
        let dir = std::env::temp_dir().join("pichol_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let events = vec![ev(0, 0), ev(1, 0)];
        write_chrome_trace(path.to_str().unwrap(), &events).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.starts_with("[\n"));
        assert!(s.trim_end().ends_with(']'));
        assert_eq!(s.matches("\"ph\":\"X\"").count(), 2);
        assert_eq!(s.matches("},").count(), 1); // one separator for two events
        std::fs::remove_file(&path).ok();
    }
}
