//! # piCholesky
//!
//! A rust + JAX/Pallas reproduction of *piCholesky: Polynomial Interpolation
//! of Multiple Cholesky Factors for Efficient Approximate Cross-Validation*
//! (Kuang, Gittens & Hamid, 2014).
//!
//! Ridge-regression cross-validation solves `(H + λI)θ = g` over k folds × q
//! candidate λ values; each solve costs an `O(d³)` Cholesky factorization, so
//! the λ sweep dominates the pipeline once `n < k·q·d` (paper Figures 1-2).
//! piCholesky computes only `g ≪ q` exact factors, fits a degree-`r`
//! polynomial to every entry of the factor as a function of λ (one big
//! least-squares problem, Algorithm 1) and *interpolates* the remaining
//! factors at `O(r·d²)` each.
//!
//! ## Architecture (three layers, python never on the request path)
//!
//! - **L1** — Pallas kernels (`python/compile/kernels/`): tiled Gram matrix,
//!   polynomial fit/eval streaming the huge `D = h(h+1)/2` axis, blocked
//!   triangular solves.
//! - **L2** — JAX graphs (`python/compile/model.py`) composing the kernels,
//!   AOT-lowered once to HLO text artifacts by `make artifacts`.
//! - **L3** — this crate: the cross-validation coordinator ([`coordinator`],
//!   [`cv`]) with its parallel fold×λ sweep engine
//!   ([`coordinator::sweep_engine`]: anchors-first scheduling over a worker
//!   pool, bit-identical results at any thread count), the shared-Gram data
//!   pipeline ([`data::gram`]: `XᵀX` assembled once per dataset, per-fold
//!   Hessians by hold-out downdate), the rank-k factor-update subsystem and
//!   its exact leave-one-out engine ([`linalg::chud`], [`cv::loo`]: anchor
//!   factors once per λ, held-out factors by rank-1 downdate), the native
//!   Algorithm-1 implementation
//!   ([`pichol`]), the LAPACK-like substrate the paper assumes ([`linalg`],
//!   including a pool-tiled blocked Cholesky), the §5 triangular
//!   vectorization strategies ([`vectorize`]), dataset synthesis and
//!   Kar–Karnick random feature maps ([`data`]), and the PJRT runtime that
//!   loads the AOT artifacts ([`runtime`] — a graceful stub unless built
//!   with `--features pjrt`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
//! use picholesky::cv::{run_cv, CvConfig};
//! use picholesky::cv::solvers::SolverKind;
//!
//! let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 512, 128, 7);
//! let cfg = CvConfig::default();
//! let report = run_cv(&ds, SolverKind::PiChol, &cfg).unwrap();
//! println!("λ* = {:.4}, holdout = {:.4}", report.best_lambda, report.best_error);
//! ```

// Clippy runs in CI with `-D warnings` (ci.sh). Three style lints are opted
// out crate-wide: numeric kernels here index with explicit loop bounds on
// purpose (fixed accumulation order, split borrows, panel offsets), the
// packed-kernel drivers take coordinate bundles that a struct would only
// obscure, and the worker pool's boxed-job vectors are inherently nested
// types.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cv;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod obs;
pub mod pichol;
pub mod prng;
pub mod runtime;
pub mod testutil;
pub mod util;
pub mod vectorize;

/// Crate-wide result type (anyhow-backed; the only external dependency apart
/// from the `xla` PJRT bindings).
pub type Result<T> = anyhow::Result<T>;
