//! Conformance-suite problem generators and assertion helpers.
//!
//! The factor-level k-fold engine ([`crate::cv::FoldStrategy::Downdate`])
//! reroutes the crate's *default* hot path, so it ships pinned against two
//! oracles: the legacy refactorize path (same curves within rounding) and
//! the leave-one-out engine (same λ neighborhood) — the validation shape of
//! Stephenson & Broderick's ACV work and Wilson et al.'s model-assessment
//! guarantees (PAPERS.md). This module owns the *problems* that suite runs
//! on and the RMS comparison it asserts with; the suite itself lives in
//! `tests/conformance.rs` and is wired into `ci.sh --conformance`.
//!
//! Three seeded generators cover the numerical regimes a fold downdate can
//! meet:
//!
//! - [`well_conditioned`] — the stock synthetic dataset (the paper's §6
//!   regime, Gram comfortably PD at every grid λ);
//! - [`ill_conditioned`] — feature columns scaled geometrically over
//!   `decades` orders of magnitude, driving the Gram's spread up by
//!   `~10^(2·decades)` while the λ shift keeps every fold factorizable;
//! - [`rank_deficient`] — features projected onto a rank-`r` subspace, so
//!   the Gram itself is singular and *only* the λ shift makes the anchors
//!   (and every downdated fold factor) positive-definite.
//!
//! All three return ordinary [`SyntheticDataset`]s, so they run through
//! every mode unchanged (`fold_strategy = refactor | downdate`,
//! `--mode loo`).

use crate::data::synthetic::{DatasetKind, SyntheticDataset};

/// The stock well-conditioned problem: the MNIST-like generator as-is.
pub fn well_conditioned(n: usize, h: usize, seed: u64) -> SyntheticDataset {
    SyntheticDataset::generate(DatasetKind::MnistLike, n, h, seed)
}

/// Geometrically ill-conditioned features: column `j` of the feature block
/// is scaled by `10^(−decades·j/(h−2))`, spreading the Gram's column norms
/// over `decades` orders of magnitude (condition number grows by roughly
/// the square of that). The intercept column is left alone, so labels and
/// the optimum's location stay in the paper's regime.
pub fn ill_conditioned(n: usize, h: usize, decades: f64, seed: u64) -> SyntheticDataset {
    let mut ds = SyntheticDataset::generate(DatasetKind::MnistLike, n, h, seed);
    let denom = (h.saturating_sub(2)).max(1) as f64;
    for j in 0..h - 1 {
        let scale = 10f64.powf(-decades * j as f64 / denom);
        for i in 0..n {
            ds.x[(i, j)] *= scale;
        }
    }
    ds
}

/// Rank-deficient features: columns `j ≥ rank` of the feature block are
/// overwritten with scaled copies of columns `j mod rank`, so the feature
/// Gram has rank ≤ `rank` (+1 for the intercept) and `H + λI` is PD only
/// thanks to the shift — the regime where a sloppy downdate would break.
pub fn rank_deficient(n: usize, h: usize, rank: usize, seed: u64) -> SyntheticDataset {
    let mut ds = SyntheticDataset::generate(DatasetKind::MnistLike, n, h, seed);
    let rank = rank.clamp(1, h - 1);
    for j in rank..h - 1 {
        let src = j % rank;
        // a deterministic non-trivial coefficient, so the copies are not
        // bitwise duplicates of their source column
        let coef = 0.5 + 0.25 * ((j as f64) * 0.71).sin();
        for i in 0..n {
            ds.x[(i, j)] = coef * ds.x[(i, src)];
        }
    }
    ds
}

/// The deterministic **breakdown-injection fixture** shared by the LOO
/// skip test and the fold-granular fallback test: coordinate 0 is zeroed
/// for every row, then row 0 gets a lone `1e9` spike there (and label
/// `+1`). The Gram's column 0 becomes exactly `1e18·e₀` — all cross
/// products are sums of exact zeros, `1e18 = 2¹⁸·5¹⁸` is exact in f64, and
/// any λ ≤ 1 rounds away under `ulp(1e18) = 128` — so removing row 0 (the
/// LOO hold-out, or a fold downdate whose validation block contains row 0)
/// hits pivot `1e18 − 1e18 = 0` at column 0: a guaranteed, bitwise-stable
/// breakdown at every anchor λ, while every other row/fold factors fine.
pub fn spiked_dataset(n: usize, h: usize, seed: u64) -> SyntheticDataset {
    let mut ds = SyntheticDataset::generate(DatasetKind::MnistLike, n, h, seed);
    for i in 0..ds.n() {
        ds.x[(i, 0)] = 0.0;
    }
    for v in ds.x.row_mut(0) {
        *v = 0.0;
    }
    ds.x[(0, 0)] = 1e9;
    ds.y[0] = 1.0;
    ds
}

/// The three-problem conformance suite at one (n, h, seed) shape, in
/// severity order.
pub fn suite(n: usize, h: usize, seed: u64) -> Vec<(&'static str, SyntheticDataset)> {
    vec![
        ("well-conditioned", well_conditioned(n, h, seed)),
        ("ill-conditioned", ill_conditioned(n, h, 2.0, seed ^ 0x111)),
        (
            "rank-deficient",
            rank_deficient(n, h, (h / 3).max(1), seed ^ 0x222),
        ),
    ]
}

/// Root-mean-square distance between two equal-length curves.
pub fn rms(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "curve length mismatch");
    assert!(!a.is_empty(), "empty curves");
    let sum: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (sum / a.len() as f64).sqrt()
}

/// Assert two curves are finite everywhere and within `tol` in RMS — the
/// conformance suite's acceptance comparator.
#[track_caller]
pub fn assert_close_rms(a: &[f64], b: &[f64], tol: f64) {
    assert!(
        a.iter().chain(b).all(|v| v.is_finite()),
        "conformance curves must be finite:\nlhs = {a:?}\nrhs = {b:?}"
    );
    let d = rms(a, b);
    assert!(
        d <= tol,
        "curves differ: RMS = {d:.3e} > tol {tol:.1e}\nlhs = {a:?}\nrhs = {b:?}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gram::GramCache;
    use crate::linalg::svd::jacobi_svd;

    #[test]
    fn ill_conditioned_spreads_the_gram() {
        let base = well_conditioned(60, 9, 3);
        let ill = ill_conditioned(60, 9, 2.0, 3);
        let cond = |ds: &SyntheticDataset| {
            let gram = GramCache::assemble(&ds.x, &ds.y);
            let svd = jacobi_svd(gram.hessian());
            svd.s[0] / svd.s.last().unwrap().max(1e-300)
        };
        assert!(
            cond(&ill) > 50.0 * cond(&base),
            "conditioning must degrade: {:.1e} vs {:.1e}",
            cond(&ill),
            cond(&base)
        );
    }

    #[test]
    fn rank_deficient_gram_is_singular() {
        let ds = rank_deficient(60, 12, 3, 4);
        let gram = GramCache::assemble(&ds.x, &ds.y);
        let svd = jacobi_svd(gram.hessian());
        // rank ≤ 3 feature columns + intercept → at most 4 significant
        // singular values out of 12
        let significant = svd.s.iter().filter(|&&s| s > 1e-10 * svd.s[0]).count();
        assert!(significant <= 4, "rank {significant} > expected 4");
    }

    #[test]
    fn rms_helper() {
        assert!(rms(&[1.0, 2.0], &[1.0, 2.0]) == 0.0);
        let d = rms(&[1.0, 1.0], &[1.0, 2.0]);
        assert!((d - (0.5f64).sqrt()).abs() < 1e-12);
        assert_close_rms(&[1.0], &[1.0 + 1e-12], 1e-9);
    }

    #[test]
    #[should_panic(expected = "curves differ")]
    fn assert_close_rms_rejects_drift() {
        assert_close_rms(&[1.0], &[2.0], 1e-9);
    }
}
