//! Deterministic fault injection — the chaos half of the numerical-trust
//! subsystem (`tests/chaos.rs`, `ci.sh --chaos`).
//!
//! Every injector here is seeded/addressed, never random at call time: a
//! chaos test names the exact row, task, or file it poisons, so the
//! assertion "the degradation landed exactly where injected and nowhere
//! else" is meaningful, and a failing run reproduces bit-for-bit.
//!
//! Two kinds of injector live here:
//!
//! - **data poison** ([`poison_row_nan`], [`poison_label_inf`],
//!   [`spike_row`]) — mutate a [`SyntheticDataset`] in place before it is
//!   handed to the engine, targeting the ingest-validation gate
//!   ([`crate::data::gram::validate_rows`]) or the breakdown-escalation
//!   ladder ([`crate::cv::recovery`]);
//! - **task poison** ([`PanicInjection`]) — a process-global armed panic
//!   that fires inside a chosen sweep-engine grid task, exercising the
//!   bounded-retry/quarantine path
//!   ([`crate::coordinator::pool::WorkerPool::map_scratch_recover`]).
//!
//! The task hook [`maybe_panic_task`] is compiled into the engine
//! unconditionally but is a single relaxed-ish atomic load when disarmed —
//! zero-cost in every non-chaos run. The armed state is **process-global**:
//! tests that arm it must serialize on a shared lock (chaos tests do) and
//! disarm via the RAII guard so a failing assertion cannot leak the armed
//! state into the next test.

use crate::data::synthetic::SyntheticDataset;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Which grid-task index is armed to panic (−1 = disarmed).
static PANIC_TASK: AtomicI64 = AtomicI64::new(-1);
/// How many executions of the armed task still panic (`u64::MAX` ≈ every
/// attempt — the quarantine case).
static PANIC_REMAINING: AtomicU64 = AtomicU64::new(0);

/// Arm the task-panic injector: the next `times` executions of grid task
/// `task` panic. Prefer the RAII [`PanicInjection::arm`] in tests.
pub fn arm_panic_at_task(task: usize, times: u64) {
    PANIC_REMAINING.store(times, Ordering::SeqCst);
    PANIC_TASK.store(task as i64, Ordering::SeqCst);
}

/// Disarm the task-panic injector.
pub fn disarm_panic() {
    PANIC_TASK.store(-1, Ordering::SeqCst);
    PANIC_REMAINING.store(0, Ordering::SeqCst);
}

/// The engine-side hook: called by every sweep grid task with its task
/// index; panics iff that index is armed with shots remaining. No-op (one
/// atomic load) when disarmed.
pub fn maybe_panic_task(task: usize) {
    if PANIC_TASK.load(Ordering::SeqCst) != task as i64 {
        return;
    }
    if PANIC_REMAINING
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |r| r.checked_sub(1))
        .is_ok()
    {
        panic!("injected fault: grid task {task} poisoned by chaos harness");
    }
}

/// RAII armed panic: arms on construction, disarms on drop — armed state
/// cannot outlive the test even when an assertion fails mid-flight.
pub struct PanicInjection(());

impl PanicInjection {
    /// Arm grid task `task` to panic on its next `times` executions.
    pub fn arm(task: usize, times: u64) -> Self {
        arm_panic_at_task(task, times);
        PanicInjection(())
    }
}

impl Drop for PanicInjection {
    fn drop(&mut self) {
        disarm_panic();
    }
}

/// Overwrite every feature of row `row` with NaN — the ingest-gate fault
/// ([`crate::data::gram::validate_rows`] must reject it by name).
pub fn poison_row_nan(ds: &mut SyntheticDataset, row: usize) {
    for v in ds.x.row_mut(row) {
        *v = f64::NAN;
    }
}

/// Set label `row` to +∞ — the label half of the ingest gate.
pub fn poison_label_inf(ds: &mut SyntheticDataset, row: usize) {
    ds.y[row] = f64::INFINITY;
}

/// Plant the conformance suite's deterministic breakdown spike on a chosen
/// row: zero feature 0 everywhere, zero the row, then a lone `1e9` at
/// `(row, 0)` with label `+1`. Any fold/hold-out whose validation block
/// contains `row` hits an exact zero pivot in its downdate (see
/// [`crate::testutil::conformance::spiked_dataset`] for the arithmetic) and
/// must be rescued by the refactor rung.
pub fn spike_row(ds: &mut SyntheticDataset, row: usize) {
    for i in 0..ds.n() {
        ds.x[(i, 0)] = 0.0;
    }
    for v in ds.x.row_mut(row) {
        *v = 0.0;
    }
    ds.x[(row, 0)] = 1e9;
    ds.y[row] = 1.0;
}

/// Write a truncated/garbage `BENCH_kernels.json` at `path` — the
/// bench-calibration fault (`fold_strategy = auto` must degrade to the
/// default strategy, never panic or parse nonsense).
pub fn write_garbage_bench_file(path: &std::path::Path) -> std::io::Result<()> {
    std::fs::write(path, "{\"schema\": 2, \"results\": [ {\"name\": \"chud_rk\", \"med")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn disarmed_hook_is_a_no_op_and_armed_shots_deplete() {
        maybe_panic_task(0);
        maybe_panic_task(7);

        // arm an index far beyond any real sweep's task count, so engine
        // tests running concurrently in this binary can never trip it
        const T: usize = 999_999;
        {
            let _guard = PanicInjection::arm(T, 2);
            maybe_panic_task(T - 1); // wrong task: untouched
            for _ in 0..2 {
                let hit = catch_unwind(AssertUnwindSafe(|| maybe_panic_task(T)));
                assert!(hit.is_err(), "armed task must panic while shots remain");
            }
            maybe_panic_task(T); // shots spent: no-op again
        }
        // guard dropped → disarmed
        maybe_panic_task(T);
    }

    #[test]
    fn spiked_row_matches_the_conformance_fixture() {
        let mut ds = crate::testutil::conformance::well_conditioned(40, 8, 5);
        spike_row(&mut ds, 0);
        let oracle = crate::testutil::conformance::spiked_dataset(40, 8, 5);
        assert_eq!(ds.x.as_slice(), oracle.x.as_slice());
        assert_eq!(ds.y, oracle.y);
    }

    #[test]
    fn poisons_target_only_their_row() {
        let mut ds = crate::testutil::conformance::well_conditioned(10, 5, 1);
        let clean = ds.x.clone();
        poison_row_nan(&mut ds, 4);
        assert!(ds.x.row(4).iter().all(|v| v.is_nan()));
        for r in (0..10).filter(|&r| r != 4) {
            assert_eq!(ds.x.row(r), clean.row(r), "row {r} must be untouched");
        }
        poison_label_inf(&mut ds, 2);
        assert!(ds.y[2].is_infinite());
    }
}
