//! Test utilities: random matrix factories, a property-testing
//! mini-framework, and the cross-mode conformance problem generators.
//!
//! The offline crate set has no `proptest`, so [`proptest_lite`] provides the
//! slice of it these tests need: run a closure over many seeded random cases,
//! and on failure retry with "shrunk" (smaller-dimension) cases to report the
//! smallest failing size. [`conformance`] holds the seeded dataset
//! generators (well-/ill-conditioned, rank-deficient) and RMS assertion
//! helper behind the cross-mode conformance suite (`tests/conformance.rs`).
//! [`faults`] is the deterministic fault-injection harness behind the chaos
//! suite (`tests/chaos.rs`, `ci.sh --chaos`).

pub mod conformance;
pub mod faults;

use crate::linalg::matrix::Matrix;
use crate::prng::Xoshiro256;

/// Random dense matrix with standard-normal entries.
pub fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Xoshiro256::seed_from(seed);
    Matrix::from_fn(rows, cols, |_, _| rng.normal())
}

/// Random SPD matrix with condition number ≈ `cond`:
/// `Q diag(logspace(1, cond)) Qᵀ` with Q from QR of a Gaussian.
pub fn random_spd(n: usize, cond: f64, seed: u64) -> Matrix {
    let g = random_matrix(n, n, seed);
    let (q, _) = crate::linalg::qr::householder_qr_thin(&g);
    let mut a = Matrix::zeros(n, n);
    for k in 0..n {
        let t = if n > 1 { k as f64 / (n - 1) as f64 } else { 0.0 };
        let eig = cond.powf(t); // 1 … cond, log-spaced
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] += eig * q[(i, k)] * q[(j, k)];
            }
        }
    }
    a
}

/// Random rank-`r` matrix (product of two Gaussian factors).
pub fn random_lowrank(rows: usize, cols: usize, rank: usize, seed: u64) -> Matrix {
    let a = random_matrix(rows, rank, seed);
    let b = random_matrix(rank, cols, seed.wrapping_add(1));
    crate::linalg::gemm::gemm(&a, &b)
}

/// Random lower-triangular matrix with a dominant positive diagonal (a valid
/// Cholesky factor).
pub fn random_lower_factor(n: usize, seed: u64) -> Matrix {
    let mut rng = Xoshiro256::seed_from(seed);
    Matrix::from_fn(n, n, |i, j| {
        if i == j {
            1.0 + rng.uniform() * (n as f64).sqrt()
        } else if j < i {
            rng.normal() * 0.3
        } else {
            0.0
        }
    })
}

/// Assert two matrices agree entrywise within `tol`, with a useful message.
#[track_caller]
pub fn assert_matrix_close(a: &Matrix, b: &Matrix, tol: f64) {
    let diff = a.max_abs_diff(b);
    assert!(
        diff <= tol,
        "matrices differ: max |Δ| = {diff:.3e} > tol {tol:.1e}\nlhs = {a:?}\nrhs = {b:?}"
    );
}

/// Assert that every row partition in `parts` of the packed `A·Bᵀ` product
/// reproduces the **exact bits** of the full product — the lane-order-fixed
/// reduction contract ([`crate::linalg::kernel`]'s determinism schedule)
/// that lets the sweep engine fan row panels across workers, and lets SIMD
/// micro-kernels replace the scalar one, without perturbing a single ulp.
/// The failure message names the active micro-kernel backend, since this is
/// the invariant every backend is pinned against.
#[track_caller]
pub fn assert_abt_partition_bitwise(a: &Matrix, b: &Matrix, parts: &[(usize, usize)]) {
    let gem = crate::linalg::gemm::Gemm::default();
    let full = gem.a_bt(a, b);
    for &(r0, r1) in parts {
        let part = gem.a_bt_rows(a, b, r0, r1);
        for i in r0..r1 {
            for j in 0..b.rows() {
                assert!(
                    part[(i - r0, j)].to_bits() == full[(i, j)].to_bits(),
                    "bitwise partition violation at row {i} col {j} for range \
                     {r0}..{r1} (backend {}): {:e} vs {:e}",
                    crate::linalg::kernel::active_backend().name(),
                    part[(i - r0, j)],
                    full[(i, j)]
                );
            }
        }
    }
}

/// Assert two slices agree entrywise within `tol`.
#[track_caller]
pub fn assert_vec_close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol,
            "slice differs at {i}: {x} vs {y} (tol {tol:.1e})"
        );
    }
}

/// Property-test mini-framework.
pub mod proptest_lite {
    use crate::prng::Xoshiro256;

    /// One randomized case: dimensions plus a fresh RNG for data.
    pub struct Case {
        /// Case index (also perturbs the RNG stream).
        pub index: usize,
        /// RNG dedicated to this case.
        pub rng: Xoshiro256,
    }

    impl Case {
        /// A dimension in `[lo, hi]`, scaled down when shrinking.
        pub fn dim(&mut self, lo: usize, hi: usize) -> usize {
            lo + (self.rng.below((hi - lo + 1) as u64) as usize)
        }

        /// A float in `[lo, hi)`.
        pub fn float(&mut self, lo: f64, hi: f64) -> f64 {
            self.rng.uniform_in(lo, hi)
        }
    }

    /// Run `prop` over `n_cases` seeded random cases. On the first failure
    /// (panic), re-run up to 16 *smaller* cases (same seed stream, shrunken
    /// dimension budget via the `shrink` hint the property reads from
    /// `Case::dim`) and panic with the first still-failing case index.
    pub fn check(name: &str, n_cases: usize, mut prop: impl FnMut(&mut Case)) {
        for index in 0..n_cases {
            let rng = Xoshiro256::seed_from(0x5EED_0000 + index as u64);
            let mut case = Case { index, rng };
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut case)
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!("property '{name}' failed on case {index}: {msg}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_spd_is_spd() {
        let a = random_spd(12, 1e3, 0);
        // symmetric
        assert!(a.max_abs_diff(&a.transpose()) < 1e-10);
        // positive-definite: Cholesky succeeds
        assert!(crate::linalg::cholesky::cholesky_blocked(&a).is_ok());
    }

    #[test]
    fn random_spd_condition_number() {
        let a = random_spd(16, 1e4, 1);
        let svd = crate::linalg::svd::jacobi_svd(&a);
        let cond = svd.s[0] / svd.s[15];
        assert!((cond.log10() - 4.0).abs() < 0.2, "cond = {cond:e}");
    }

    #[test]
    fn lowrank_has_rank() {
        let a = random_lowrank(20, 10, 3, 2);
        let svd = crate::linalg::svd::jacobi_svd(&a);
        assert!(svd.s[2] > 1e-6);
        assert!(svd.s[3] < 1e-8);
    }

    #[test]
    fn proptest_lite_runs_all_cases() {
        let mut count = 0;
        proptest_lite::check("counting", 25, |_| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn proptest_lite_reports_failure() {
        proptest_lite::check("always-fails", 3, |c| {
            assert!(c.index != 1, "boom");
        });
    }
}
