//! Minimal, dependency-free drop-in for the `anyhow` error crate.
//!
//! The build environment for this repository is fully offline, so instead of
//! pulling `anyhow` from a registry the crate vendors the thin slice of its
//! API that the codebase actually uses:
//!
//! - [`Error`]: an opaque, `Send + Sync` error value carrying a context
//!   chain (outermost context first, root cause last);
//! - [`Result<T>`]: alias for `std::result::Result<T, Error>`;
//! - [`anyhow!`], [`bail!`], [`ensure!`]: the formatting macros;
//! - [`Context`]: `.context(...)` / `.with_context(|| ...)` on any
//!   `Result<T, E>` whose error is `std::error::Error` or already an
//!   [`Error`];
//! - `From<E: std::error::Error + Send + Sync + 'static>` so `?` converts
//!   concrete errors (I/O, [`std::fmt::Error`], the crate's own typed
//!   errors) into [`Error`].
//!
//! Display follows `anyhow` conventions: `{}` prints the outermost context
//! only, `{:#}` prints the whole chain joined with `": "`, and `{:?}` prints
//! the message plus a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a chain of human-readable messages, outermost context
/// first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root-cause message (innermost entry of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` impl below coherent (the same trick the real
// `anyhow` uses).
impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod ext {
    use super::{Error, StdError};

    /// Anything convertible into an [`Error`] — concrete std errors or an
    /// [`Error`] itself (mirrors `anyhow`'s private `ext::StdError`).
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> Error;
    }

    impl<E> IntoAnyhow for E
    where
        E: StdError + Send + Sync + 'static,
    {
        fn into_anyhow(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoAnyhow for Error {
        fn into_anyhow(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(|| ...)` to
/// `Result`s.
pub trait Context<T> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoAnyhow> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().root_cause(), "file missing");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("root {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root 7");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let mut called = false;
        let r: std::result::Result<i32, std::io::Error> = Ok(3);
        let v = r
            .with_context(|| {
                called = true;
                "never shown"
            })
            .unwrap();
        assert_eq!(v, 3);
        assert!(!called, "with_context closure ran on Ok");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 10 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
