//! Ablation benches over the design choices DESIGN.md §4 calls out:
//! sample count g × degree r, Cholesky panel width, recursive-vectorization
//! base threshold h₀.
//!
//! `cargo bench --bench bench_ablations`

use picholesky::experiments::ablations;

fn main() {
    let gr = ablations::run_gr(96, 0xAB1A);
    gr.print();
    gr.write_to("results/bench").expect("write results");

    let block = ablations::run_chol_block(768, &[8, 16, 32, 64, 128, 256], 3, 0xAB1B);
    block.print();
    block.write_to("results/bench").expect("write results");

    let h0 = ablations::run_recursive_h0(2048, &[4, 8, 16, 32, 64, 128, 256, 512], 10, 0xAB1C);
    h0.print();
    h0.write_to("results/bench").expect("write results");
}
