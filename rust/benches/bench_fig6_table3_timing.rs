//! Regenerates **Figure 6** (total CV seconds of the six algorithms vs h on
//! MNIST-like data) and **Table 3** (per-fold seconds at the largest h across
//! all four datasets).
//!
//! `cargo bench --bench bench_fig6_table3_timing`

use picholesky::coordinator::Coordinator;
use picholesky::cv::CvConfig;
use picholesky::experiments::fig6_table3;

fn main() {
    let coord = Coordinator::default();
    let cfg = CvConfig::default(); // paper: k=5 folds, q=31, g=4, r=2

    let fig6 = fig6_table3::run_fig6(&coord, &[64, 128, 192], 6, &cfg);
    fig6.print();
    fig6.write_to("results/bench").expect("write results");

    let table3 = fig6_table3::run_table3(&coord, 768, 192, &cfg);
    table3.print();
    table3.write_to("results/bench").expect("write results");
}
