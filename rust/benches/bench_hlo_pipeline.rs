//! Perf harness for the AOT request path: per-artifact wall times at each
//! lowered shape config, plus the piCholesky-vs-exact fold comparison.
//! This is the measurement tool behind EXPERIMENTS.md §Perf (L2).
//!
//! `cargo bench --bench bench_hlo_pipeline` (requires `make artifacts`)

use picholesky::coordinator::{HloFold, HloPipeline, Metrics};
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::runtime::{Engine, Tensor};
use picholesky::util::fmt_secs;

fn main() {
    let engine = Engine::new("artifacts").expect("run `make artifacts` first");
    println!("platform: {}", engine.platform());

    for h in [64usize, 128, 256] {
        let Ok(cfg) = engine.config(h, Some(4), Some(2)) else {
            continue;
        };
        let total = cfg.n + cfg.n_val;
        let ds = SyntheticDataset::generate(DatasetKind::MnistLike, total, cfg.h, 99);
        let fold = HloFold {
            xt: ds.x.slice(0, cfg.n, 0, cfg.h),
            yt: ds.y[..cfg.n].to_vec(),
            xv: ds.x.slice(cfg.n, total, 0, cfg.h),
            yv: ds.y[cfg.n..].to_vec(),
        };
        let metrics = Metrics::new();
        let pipe = HloPipeline::new(&engine, cfg, &metrics);
        pipe.warmup().expect("warmup");

        let (lo, hi) = ds.kind.lambda_range();
        let grid = pipe.grid(lo, hi);
        let sample = pipe.sample_lambdas(&grid);

        // per-stage timing over `reps` runs
        let reps = 3;
        let (mut h_t, mut g_t) = pipe.gram(&fold).expect("gram");
        for _ in 0..reps {
            let (a, b) = pipe.gram(&fold).expect("gram");
            h_t = a;
            g_t = b;
        }
        let mut theta = pipe.fit(&h_t, &sample).expect("fit");
        for _ in 0..reps {
            theta = pipe.fit(&h_t, &sample).expect("fit");
        }
        for _ in 0..reps {
            pipe.sweep(&theta, &grid, &g_t, &fold).expect("sweep");
            pipe.exact_sweep(&h_t, &grid, &g_t, &fold).expect("exact");
        }
        // single-λ exact solve for the per-factorization cost
        for _ in 0..reps {
            engine
                .run(
                    cfg,
                    "chol_solve",
                    &[h_t.clone(), Tensor::scalar(0.1), g_t.clone()],
                )
                .expect("chol_solve");
        }

        println!("\n== h = {h} (n = {}, D = {}) ==", cfg.n, cfg.d_tri);
        for (name, calls) in [
            ("hlo.gram", reps + 1),
            ("hlo.cholvec", reps + 1),
            ("hlo.polyfit", reps + 1),
            ("hlo.sweep", reps),
            ("hlo.exact_sweep", reps),
        ] {
            println!(
                "  {name:<18} {} per call",
                fmt_secs(metrics.seconds(name) / calls as f64)
            );
        }
        let per_chol_solve = {
            // chol_solve isn't in the pipeline metrics; time directly
            let t0 = std::time::Instant::now();
            engine
                .run(
                    cfg,
                    "chol_solve",
                    &[h_t.clone(), Tensor::scalar(0.1), g_t.clone()],
                )
                .expect("chol_solve");
            t0.elapsed().as_secs_f64()
        };
        println!("  chol_solve (1 λ)   {} per call", fmt_secs(per_chol_solve));
        let sweep_s = metrics.seconds("hlo.sweep") / reps as f64;
        let exact_s = metrics.seconds("hlo.exact_sweep") / reps as f64;
        let fit_s = (metrics.seconds("hlo.cholvec") + metrics.seconds("hlo.polyfit"))
            / (reps + 1) as f64;
        println!(
            "  fold totals: pichol fit+sweep = {}, exact sweep = {} ({:.2}× ratio)",
            fmt_secs(fit_s + sweep_s),
            fmt_secs(exact_s),
            exact_s / (fit_s + sweep_s)
        );
    }
}
