//! Regenerates **Figure 9** (|log₁₀ λ_sel/λ_opt| vs wall-time for
//! Chol/PIChol/MChol) and **Figure 10** (PINRMSE vs PIChol interpolation
//! quality across datasets).
//!
//! `cargo bench --bench bench_fig9_fig10_convergence`

use picholesky::coordinator::Coordinator;
use picholesky::cv::CvConfig;
use picholesky::data::synthetic::DatasetKind;
use picholesky::experiments::{fig10, fig9};

fn main() {
    let cfg = CvConfig::default();

    // Figure 9 on the two datasets the paper uses (COIL-100, Caltech-101)
    for kind in [DatasetKind::CoilLike, DatasetKind::Caltech101Like] {
        let rep = fig9::run(kind, 640, 160, &cfg, 0xF169);
        rep.print();
        rep.write_to("results/bench").expect("write results");
    }

    let coord = Coordinator::default();
    let f10 = fig10::run(&coord, &DatasetKind::all(), 512, 96, &cfg);
    f10.print();
    f10.write_to("results/bench").expect("write results");
}
