//! Regenerates **Table 1**: vec / fit / interp seconds for row-wise,
//! full-matrix and recursive vectorization over a dimension sweep.
//!
//! `cargo bench --bench bench_table1_vectorize`
//! (paper dims 1024–16384; defaults here scale to a 1-core box, override
//! with PICHOL_BENCH_DIMS="256,512,1024").

use picholesky::experiments::table1;

fn dims_from_env(default: &[usize]) -> Vec<usize> {
    std::env::var("PICHOL_BENCH_DIMS")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect()
        })
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| default.to_vec())
}

fn main() {
    let dims = dims_from_env(&[256, 512, 1024, 2048]);
    // paper setting: g=4 factors, 31-point interpolation grid
    let report = table1::run(&dims, 4, 31, 0xBE7C);
    report.print();
    report.write_to("results/bench").expect("write results");
}
