//! Regenerates **Figure 2**: % of pipeline time in Hessian build vs the
//! cross-validation Cholesky sweep vs everything else, over (n, h).
//!
//! `cargo bench --bench bench_fig2_pipeline`

use picholesky::experiments::fig2;

fn main() {
    let ns = [512, 1024, 2048, 4096];
    let hs = [64, 128, 256];
    let report = fig2::run(&ns, &hs, 31, 0xF162);
    report.print();
    report.write_to("results/bench").expect("write results");
}
