//! Kernel-level perf harness: the packed register-blocked micro-kernel
//! engine vs the legacy blocked-loop reference, plus a full-sweep
//! end-to-end number. Seeds the repository's perf trajectory by emitting
//! `BENCH_kernels.json` at the repository root.
//!
//! ```bash
//! cargo bench --bench bench_kernels             # full sizes d ∈ {128, 256, 512}
//! cargo bench --bench bench_kernels -- --smoke  # tiny sizes, CI harness gate
//! ```
//!
//! Measured kernels (min-of-reps wall time):
//! - `gemm`  — `C = A·B`, d×d×d: packed `Gemm::mul` vs `reference::mul`
//! - `syrk`  — `H = XᵀX`, X 2d×d: packed `syrk_lower` vs `reference::syrk_lower`
//! - `trsm`  — `X·L11⁻ᵀ`, d rows × 64-wide panel: blocked packed solve vs
//!   the legacy scalar substitution loop
//! - `cholesky` — full `chol(H + λI)` at panel width 64 (packed TRSM+SYRK
//!   path; no legacy counterpart retained, reported packed-only)
//! - `gram_k5` / `gram_k10` — fold-prep data path at k ∈ {5, 10}: one
//!   shared-Gram assembly + k downdates (packed) vs k per-fold
//!   materialize+SYRK builds (reference) — the O(k·nd²) → O(nd²) change
//! - `chud_r1` / `chud_rk` — the factor-update subsystem: rank-1 / rank-16
//!   Cholesky downdate of a held factor (packed trailing panels) vs a full
//!   O(d³) refactorization of the perturbed matrix (reference)
//! - `kfold_downdate` — the factor-level k-fold engine end-to-end:
//!   `run_cv(Chol)` under `fold_strategy=downdate` (packed: one
//!   `chol(G+λI)` per grid λ + k rank-n_v downdate chains each) vs
//!   `fold_strategy=refactor` (reference: k·q per-cell `chol(H_f+λI)`), in
//!   the small-fold regime (k = 16 folds, n = d/2 ⇒ n_v ≪ d) where the
//!   chain replaces `(k−1)·O(d³)` per anchor with `O(n·d²)`
//! - `loo_sweep` — exact leave-one-out CV at n=2d through the downdate
//!   engine vs brute-force per-row refactorization (reference at small d
//!   only; the point of the subsystem is that brute force stops scaling).
//!   Also emits and asserts the structural phase counts (`factor ==
//!   anchors`, `downdate == n` per anchor, zero per-row factorizations) as
//!   a `loo_phases` object in the JSON.
//! - `aloocv_sweep` — the O(n·d) approximate-LOO tier at the same shapes
//!   and grid as `loo_sweep`: per anchor one batched multi-RHS TRSM for
//!   the hat diagonals instead of n rank-1 downdate chains. Its
//!   `reference_secs` is the measured `loo_sweep` wall, so `speedup` is
//!   the ladder's headline tier-vs-tier number. Emits and asserts the
//!   structural counts (`factor == anchors`, zero per-row factorizations
//!   AND zero per-row downdates) as an `aloocv_phases` object.
//! - `sweep` — end-to-end `run_cv` (PiChol, k=3) at n=2d (packed-only)
//! - `service_replay` / `service_query` — the streaming service end-to-end:
//!   the deterministic traffic replay (seeded rows admitted in fixed
//!   batches through the bounded queue into the sliding-window Gram, point
//!   queries interleaved). `service_replay` carries the per-batch admission
//!   latency quantiles, `service_query` the per-query snapshot-serve
//!   latency quantiles — the streaming tier's p50/p99 acceptance numbers.

use std::time::Instant;

use picholesky::cv::aloocv::run_aloocv;
use picholesky::cv::loo::{brute_force_loo_rmse, run_loo};
use picholesky::cv::solvers::SolverKind;
use picholesky::cv::{run_cv, CvConfig, FoldStrategy};
use picholesky::data::folds::kfold;
use picholesky::data::gram::GramCache;
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::linalg::cholesky::{cholesky_blocked, cholesky_in_place};
use picholesky::linalg::chud::{chol_downdate, chol_downdate_rank1};
use picholesky::linalg::gemm::{gemv_t, gram_downdate, reference, syrk_lower, Gemm};
use picholesky::linalg::matrix::Matrix;
use picholesky::linalg::triangular::trsm_right_lower_t_inplace;
use picholesky::obs::hist::Hist;
use picholesky::testutil::{random_matrix, random_spd};

/// One measured comparison (reference_secs = 0 ⇒ packed-only). Alongside
/// the min-of-reps wall, every packed rep lands in a log-bucketed latency
/// histogram so the JSON carries p50/p99 per stage — the same bucket math
/// as the engine's observability layer ([`picholesky::obs::hist`]).
struct Row {
    kernel: &'static str,
    d: usize,
    packed_secs: f64,
    reference_secs: f64,
    packed_hist: Hist,
}

impl Row {
    fn speedup(&self) -> f64 {
        if self.reference_secs > 0.0 && self.packed_secs > 0.0 {
            self.reference_secs / self.packed_secs
        } else {
            0.0
        }
    }
}

/// Min-of-reps wall time of `f`, plus the per-rep latency histogram.
fn time_reps(reps: usize, mut f: impl FnMut()) -> (f64, Hist) {
    let mut best = f64::INFINITY;
    let mut hist = Hist::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        let secs = t0.elapsed().as_secs_f64();
        best = best.min(secs);
        hist.record_secs(secs);
    }
    (best, hist)
}

/// Reference-side timing: min only (quantiles are reported for the packed
/// path, the side the trajectory tracks).
fn time_min(reps: usize, f: impl FnMut()) -> f64 {
    time_reps(reps, f).0
}

/// A one-shot measurement as (secs, single-sample histogram).
fn one_shot(secs: f64) -> (f64, Hist) {
    let mut hist = Hist::new();
    hist.record_secs(secs);
    (secs, hist)
}

/// The legacy all-scalar panel TRSM (what `cholesky_in_place` shipped
/// before the blocked rewrite), kept here as the baseline.
fn trsm_scalar_reference(x: &mut Matrix, l: &Matrix) {
    let nb = l.rows();
    for i in 0..x.rows() {
        for j in 0..nb {
            let mut s = x[(i, j)];
            for k in 0..j {
                s -= x[(i, k)] * l[(j, k)];
            }
            x[(i, j)] = s / l[(j, j)];
        }
    }
}

fn bench_size(d: usize, reps: usize, rows: &mut Vec<Row>) {
    let gem = Gemm::default();

    // GEMM: d×d×d
    let a = random_matrix(d, d, 0xA0 + d as u64);
    let b = random_matrix(d, d, 0xB0 + d as u64);
    let (packed, packed_hist) = time_reps(reps, || {
        std::hint::black_box(gem.mul(&a, &b));
    });
    let refr = time_min(reps, || {
        std::hint::black_box(reference::mul(64, &a, &b));
    });
    rows.push(Row {
        kernel: "gemm",
        d,
        packed_secs: packed,
        reference_secs: refr,
        packed_hist,
    });

    // SYRK: X is 2d×d (the Hessian-build shape)
    let x = random_matrix(2 * d, d, 0xC0 + d as u64);
    let (packed, packed_hist) = time_reps(reps, || {
        std::hint::black_box(syrk_lower(&x));
    });
    let refr = time_min(reps, || {
        std::hint::black_box(reference::syrk_lower(64, &x));
    });
    rows.push(Row {
        kernel: "syrk",
        d,
        packed_secs: packed,
        reference_secs: refr,
        packed_hist,
    });

    // TRSM: d rows against a 64-wide (or d-wide, if smaller) panel
    let nb = 64.min(d);
    let spd = random_spd(nb, 1e3, 0xD0 + d as u64);
    let l11 = cholesky_blocked(&spd).expect("panel chol");
    let rhs = random_matrix(d, nb, 0xE0 + d as u64);
    let (packed, packed_hist) = time_reps(reps, || {
        let mut t = rhs.clone();
        trsm_right_lower_t_inplace(&mut t, 0, d, 0, &l11);
        std::hint::black_box(t[(d - 1, nb - 1)]);
    });
    let refr = time_min(reps, || {
        let mut t = rhs.clone();
        trsm_scalar_reference(&mut t, &l11);
        std::hint::black_box(t[(d - 1, nb - 1)]);
    });
    rows.push(Row {
        kernel: "trsm",
        d,
        packed_secs: packed,
        reference_secs: refr,
        packed_hist,
    });

    // full factorization, packed path only (trajectory seed)
    let h = random_spd(d, 1e4, 0xF0 + d as u64);
    let (packed, packed_hist) = time_reps(reps, || {
        let mut c = h.clone();
        cholesky_in_place(&mut c, 64).expect("chol");
        std::hint::black_box(c[(d - 1, d - 1)]);
    });
    rows.push(Row {
        kernel: "cholesky",
        d,
        packed_secs: packed,
        reference_secs: 0.0,
        packed_hist,
    });
}

/// The fold-prep data path at n = 2d: shared-Gram assembly + k downdates
/// (the pipeline) vs k per-fold materialize+SYRK builds (what it replaced).
fn bench_gram(d: usize, reps: usize, rows: &mut Vec<Row>) {
    let n = 2 * d;
    let x = random_matrix(n, d, 0x6A + d as u64);
    let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    for &(k, label) in &[(5usize, "gram_k5"), (10usize, "gram_k10")] {
        let folds = kfold(n, k, 7);
        let (packed, packed_hist) = time_reps(reps, || {
            let gram = GramCache::assemble(&x, &y);
            let mut h_out = Matrix::zeros(0, 0);
            let mut g_out = Vec::new();
            for f in &folds {
                let (xv, yv) = f.materialize_val(&x, &y);
                gram_downdate(gram.hessian(), gram.gradient(), &xv, &yv, &mut h_out, &mut g_out);
                std::hint::black_box(h_out[(d - 1, d - 1)]);
            }
        });
        let refr = time_min(reps, || {
            for f in &folds {
                let (xt, yt) = f.materialize_train(&x, &y);
                let h = syrk_lower(&xt);
                let g = gemv_t(&xt, &yt);
                std::hint::black_box((h[(d - 1, d - 1)], g[0]));
            }
        });
        rows.push(Row {
            kernel: label,
            d,
            packed_secs: packed,
            reference_secs: refr,
            packed_hist,
        });
    }
}

/// The factor-update subsystem: rank-1 and rank-16 downdate of a held
/// factor (the LOO / streaming-window kernels) vs refactorizing the
/// perturbed matrix from scratch — the O(d²) vs O(d³) trade the subsystem
/// exists for. Factor copy included on the packed side: that is the real
/// per-held-out-row operation.
fn bench_chud(d: usize, reps: usize, rows: &mut Vec<Row>) {
    let x = random_matrix(2 * d, d, 0x1C0 + d as u64);
    let mut a = syrk_lower(&x);
    a.add_diag_in_place(1.0); // A − U·Uᵀ stays safely PD
    let l0 = cholesky_blocked(&a).expect("base factor");
    let mut trans = Matrix::zeros(0, 0);

    // rank-1: downdate by one row of X
    let v: Vec<f64> = x.row(0).to_vec();
    let mut lbuf = l0.clone();
    let mut vbuf = v.clone();
    let (packed, packed_hist) = time_reps(reps, || {
        lbuf.copy_from(&l0);
        vbuf.clear();
        vbuf.extend_from_slice(&v);
        chol_downdate_rank1(&mut lbuf, &mut vbuf, &mut trans).expect("r1 downdate");
        std::hint::black_box(lbuf[(d - 1, d - 1)]);
    });
    let mut abuf = a.clone();
    let refr = time_min(reps, || {
        abuf.copy_from(&a);
        for i in 0..d {
            for j in 0..d {
                abuf[(i, j)] -= v[i] * v[j];
            }
        }
        cholesky_in_place(&mut abuf, 64).expect("refactorization");
        std::hint::black_box(abuf[(d - 1, d - 1)]);
    });
    rows.push(Row {
        kernel: "chud_r1",
        d,
        packed_secs: packed,
        reference_secs: refr,
        packed_hist,
    });

    // rank-k (k = 16): a retired row block, one blocked downdate
    let k = d.min(16);
    let u0 = x.slice(0, k, 0, d).transpose(); // d×k
    let mut ubuf = u0.clone();
    let (packed, packed_hist) = time_reps(reps, || {
        lbuf.copy_from(&l0);
        ubuf.copy_from(&u0);
        chol_downdate(&mut lbuf, &mut ubuf, &mut trans).expect("rk downdate");
        std::hint::black_box(lbuf[(d - 1, d - 1)]);
    });
    let uut = Gemm::default().a_bt(&u0, &u0);
    let refr = time_min(reps, || {
        abuf.copy_from(&a);
        for i in 0..d {
            for j in 0..d {
                abuf[(i, j)] -= uut[(i, j)];
            }
        }
        cholesky_in_place(&mut abuf, 64).expect("refactorization");
        std::hint::black_box(abuf[(d - 1, d - 1)]);
    });
    rows.push(Row {
        kernel: "chud_rk",
        d,
        packed_secs: packed,
        reference_secs: refr,
        packed_hist,
    });
}

/// End-to-end LOO sweep at n = 2d through the downdate engine. Brute-force
/// per-row refactorization baseline only at small d (it is the O(n·d³)
/// path the engine exists to avoid). Returns the `loo_phases` JSON object
/// proving the downdate path did zero per-row O(d³) factorizations, plus
/// the measured wall (the reference side of `aloocv_sweep`).
fn bench_loo(d: usize, rows: &mut Vec<Row>) -> (String, f64) {
    let n = 2 * d;
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, n, d, 7);
    let cfg = CvConfig {
        q_grid: 20,
        g_samples: 4,
        lambda_range: Some((0.1, 1.0)),
        sweep_threads: 1, // single-threaded: kernel speed, not parallelism
        ..CvConfig::default()
    };
    let t0 = Instant::now();
    let rep = run_loo(&ds, &cfg).expect("loo sweep");
    let (packed, packed_hist) = one_shot(t0.elapsed().as_secs_f64());
    std::hint::black_box(rep.best_lambda);

    let refr = if d <= 64 {
        let t0 = Instant::now();
        std::hint::black_box(brute_force_loo_rmse(&ds, &rep.anchor_lambdas));
        t0.elapsed().as_secs_f64()
    } else {
        0.0 // O(n·d³): pointless to wait on at full sizes
    };
    rows.push(Row {
        kernel: "loo_sweep",
        d,
        packed_secs: packed,
        reference_secs: refr,
        packed_hist,
    });

    // the acceptance invariant, asserted AND recorded in the trajectory
    let anchors = rep.anchor_lambdas.len() as u64;
    let factor = rep.timer.count("factor");
    let downdate = rep.timer.count("downdate");
    assert_eq!(factor, anchors, "factor phase must run once per anchor");
    assert_eq!(
        downdate,
        n as u64 * anchors,
        "downdate phase must run once per (row, anchor)"
    );
    assert_eq!(rep.timer.count("chol"), 0, "no per-row O(d³) factorization");
    let phases = format!(
        "{{\"d\": {d}, \"n\": {n}, \"anchors\": {anchors}, \"factor\": {factor}, \
         \"downdate\": {downdate}, \"per_row_chol\": 0}}"
    );
    (phases, packed)
}

/// The O(n·d) ALOOCV tier at the exact shapes and grid of `bench_loo`, so
/// the two walls are directly comparable: per anchor, one batched
/// multi-RHS TRSM through the packed kernel (hat diagonals as column
/// norms of `L⁻¹Xᵀ`) replaces exact LOO's n rank-1 downdate chains.
/// `reference_secs` is the measured `loo_sweep` wall — `speedup` is the
/// ladder's tier-vs-tier headline. Returns the `aloocv_phases` JSON
/// object proving the fast path did zero per-row factor work.
fn bench_aloocv(d: usize, loo_secs: f64, rows: &mut Vec<Row>) -> String {
    let n = 2 * d;
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, n, d, 7);
    let cfg = CvConfig {
        q_grid: 20,
        g_samples: 4,
        lambda_range: Some((0.1, 1.0)),
        sweep_threads: 1, // single-threaded: kernel speed, not parallelism
        ..CvConfig::default()
    };
    let t0 = Instant::now();
    let rep = run_aloocv(&ds, &cfg).expect("aloocv sweep");
    let (packed, packed_hist) = one_shot(t0.elapsed().as_secs_f64());
    std::hint::black_box(rep.best_lambda);
    rows.push(Row {
        kernel: "aloocv_sweep",
        d,
        packed_secs: packed,
        reference_secs: loo_secs,
        packed_hist,
    });

    // the acceptance invariant, asserted AND recorded in the trajectory
    let anchors = rep.anchor_lambdas.len() as u64;
    let factor = rep.timer.count("factor");
    let hat = rep.timer.count("hat_solve");
    assert_eq!(factor, anchors, "factor phase must run once per anchor");
    assert!(hat >= anchors, "at least one batched hat solve per anchor");
    assert_eq!(hat % anchors, 0, "hat solves come in per-anchor batches");
    assert_eq!(rep.timer.count("chol"), 0, "no per-row O(d³) factorization");
    assert_eq!(
        rep.timer.count("downdate"),
        0,
        "no per-row downdates on the hat-diagonal fast path"
    );
    format!(
        "{{\"d\": {d}, \"n\": {n}, \"anchors\": {anchors}, \"factor\": {factor}, \
         \"hat_solve\": {hat}, \"per_row_chol\": 0, \"per_row_downdate\": 0}}"
    )
}

/// The factor-level k-fold engine vs per-cell refactorization, end-to-end
/// through `run_cv(Chol)`. Shaped for the regime the downdate chain exists
/// for — many small folds (k = 16, n = d/2 ⇒ n_v = d/32 validation rows per
/// fold): per anchor λ the downdate side does one `chol(G+λI)` plus
/// `O(n·d²)` of chained downdates against the refactor side's k `O(d³)`
/// factorizations. Both sides share the Gram pipeline, solves and scoring,
/// so the delta is exactly the fold-factor production.
fn bench_kfold(d: usize, reps: usize, rows: &mut Vec<Row>) {
    let k = 16usize;
    let n = (d / 2).max(2 * k);
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, n, d, 7);
    let base = CvConfig {
        k_folds: k,
        q_grid: 16,
        lambda_range: Some((0.1, 1.0)),
        sweep_threads: 1, // single-threaded: kernel speed, not parallelism
        ..CvConfig::default()
    };
    let (packed, packed_hist) = time_reps(reps, || {
        let cfg = CvConfig {
            fold_strategy: FoldStrategy::Downdate,
            ..base.clone()
        };
        let rep = run_cv(&ds, SolverKind::Chol, &cfg).expect("kfold downdate");
        assert!(rep.degradations.is_empty(), "bench problem must not break down");
        std::hint::black_box(rep.best_lambda);
    });
    let refr = time_min(reps, || {
        let cfg = CvConfig {
            fold_strategy: FoldStrategy::Refactor,
            ..base.clone()
        };
        let rep = run_cv(&ds, SolverKind::Chol, &cfg).expect("kfold refactor");
        std::hint::black_box(rep.best_lambda);
    });
    rows.push(Row {
        kernel: "kfold_downdate",
        d,
        packed_secs: packed,
        reference_secs: refr,
        packed_hist,
    });
}

/// The streaming-service traffic replay end-to-end: admission through the
/// bounded queue, per-row window numerics, periodic snapshot refreshes,
/// interleaved point queries against the epoch-swapped snapshot. Emits two
/// rows sharing the replay wall: `service_replay` with the admission
/// (validate + queue wait) latency histogram, `service_query` with the
/// snapshot-serve latency histogram.
fn bench_service(d: usize, smoke: bool, rows: &mut Vec<Row>) {
    use picholesky::coordinator::service::{run_replay, ReplayConfig};
    use picholesky::cv::window::ServiceConfig;

    let replay = ReplayConfig {
        rows: if smoke { 640 } else { 2048 },
        dim: d,
        batch: 8,
        queries_per_batch: 4,
        kind: DatasetKind::MnistLike,
        seed: 42,
    };
    let svc = ServiceConfig {
        window: if smoke { 512 } else { 1024 },
        refresh_every: if smoke { 32 } else { 128 },
        workers: 1, // single-threaded eval: kernel speed, not parallelism
        ..ServiceConfig::default()
    };
    let cfg = CvConfig {
        q_grid: 20,
        g_samples: 4,
        lambda_range: Some((0.1, 1.0)),
        ..CvConfig::default()
    };
    let rep = run_replay(replay, svc, cfg);
    assert_eq!(rep.rows_admitted as usize, replay.rows, "replay must admit everything");
    assert!(rep.refreshes > 1, "replay must refresh repeatedly");
    assert!(
        rep.final_snapshot.best_lambda.is_finite(),
        "replay must end serving a model"
    );
    std::hint::black_box(rep.final_snapshot.best_lambda);
    rows.push(Row {
        kernel: "service_replay",
        d,
        packed_secs: rep.wall_secs,
        reference_secs: 0.0,
        packed_hist: rep.admit_hist,
    });
    rows.push(Row {
        kernel: "service_query",
        d,
        packed_secs: rep.wall_secs,
        reference_secs: 0.0,
        packed_hist: rep.query_hist,
    });
}

fn bench_sweep(d: usize, rows: &mut Vec<Row>) {
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 2 * d, d, 7);
    let cfg = CvConfig {
        k_folds: 3,
        q_grid: 20,
        sweep_threads: 1, // single-threaded: kernel speed, not parallelism
        ..CvConfig::default()
    };
    let t0 = Instant::now();
    let rep = run_cv(&ds, SolverKind::PiChol, &cfg).expect("sweep");
    std::hint::black_box(rep.best_lambda);
    let (packed, packed_hist) = one_shot(t0.elapsed().as_secs_f64());
    rows.push(Row {
        kernel: "sweep",
        d,
        packed_secs: packed,
        reference_secs: 0.0,
        packed_hist,
    });
}

fn emit_json(rows: &[Row], smoke: bool, loo_phases: &str, aloocv_phases: &str, path: &str) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"kernels\",\n");
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str(&format!(
        "  \"kernel_backend\": \"{}\",\n",
        picholesky::linalg::active_backend().name()
    ));
    s.push_str("  \"unit\": \"seconds (min of reps)\",\n");
    s.push_str(&format!("  \"loo_phases\": {loo_phases},\n"));
    s.push_str(&format!("  \"aloocv_phases\": {aloocv_phases},\n"));
    s.push_str("  \"results\": [\n");
    // p50/p99 next to the wall means: per-rep packed latencies through the
    // observability layer's log-bucketed histogram (bucket upper bounds, µs)
    let q = |h: &Hist, p: f64| match h.quantile_us(p) {
        Some(us) => format!("{us:.3}"),
        None => "null".to_string(),
    };
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"d\": {}, \"packed_secs\": {:.6e}, \
             \"reference_secs\": {:.6e}, \"speedup\": {:.3}, \
             \"p50_us\": {}, \"p99_us\": {}}}{}\n",
            r.kernel,
            r.d,
            r.packed_secs,
            r.reference_secs,
            r.speedup(),
            q(&r.packed_hist, 0.50),
            q(&r.packed_hist, 0.99),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    // Write via temp file + atomic rename: a reader racing the bench (the
    // auto-strategy picker, a `--bench-smoke` CI grep) must never observe a
    // truncated JSON, and a crashed bench must not leave one behind.
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, s).expect("write BENCH_kernels.json temp file");
    std::fs::rename(&tmp, path).expect("rename BENCH_kernels.json into place");
    println!("\nwrote {path}");
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    // --out <path>: divert the JSON (ci.sh validates smoke runs into an
    // untracked scratch file so they never overwrite the full-size
    // BENCH_kernels.json perf trajectory)
    let out_override = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let (sizes, reps): (Vec<usize>, usize) = if smoke {
        (vec![32, 48], 1)
    } else {
        (vec![128, 256, 512], 3)
    };

    let mut rows = Vec::new();
    for &d in &sizes {
        eprintln!("benching d = {d} …");
        bench_size(d, reps, &mut rows);
        bench_gram(d, reps, &mut rows);
        bench_chud(d, reps, &mut rows);
        bench_kfold(d, reps, &mut rows);
    }
    // end-to-end sweeps at the middle size (the trajectory headline numbers)
    bench_sweep(if smoke { 32 } else { 256 }, &mut rows);
    let (loo_phases, loo_secs) = bench_loo(if smoke { 32 } else { 256 }, &mut rows);
    let aloocv_phases = bench_aloocv(if smoke { 32 } else { 256 }, loo_secs, &mut rows);
    bench_service(if smoke { 32 } else { 256 }, smoke, &mut rows);

    println!("\n| kernel | d | packed | reference | speedup |");
    println!("|---|---|---|---|---|");
    for r in &rows {
        println!(
            "| {} | {} | {:.3}ms | {} | {} |",
            r.kernel,
            r.d,
            r.packed_secs * 1e3,
            if r.reference_secs > 0.0 {
                format!("{:.3}ms", r.reference_secs * 1e3)
            } else {
                "—".to_string()
            },
            if r.speedup() > 0.0 {
                format!("{:.2}×", r.speedup())
            } else {
                "—".to_string()
            },
        );
    }

    println!("\nloo phase counts: {loo_phases}");
    println!("aloocv phase counts: {aloocv_phases}");
    let default_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json");
    let path = out_override.as_deref().unwrap_or(default_path);
    emit_json(&rows, smoke, &loo_phases, &aloocv_phases, path);
}
