//! Regenerates **Figures 7-8** (hold-out error vs λ for the six algorithms
//! on all four datasets) and **Table 4** (min hold-out error + selected λ).
//!
//! `cargo bench --bench bench_fig7_table4_holdout`

use picholesky::coordinator::Coordinator;
use picholesky::cv::CvConfig;
use picholesky::data::synthetic::DatasetKind;
use picholesky::experiments::fig7_table4;

fn main() {
    let coord = Coordinator::default();
    let cfg = CvConfig::default();
    let (n, h) = (640, 160);

    let fig7 = fig7_table4::run_fig7_8(&coord, &DatasetKind::all(), n, h, &cfg);
    fig7.print();
    fig7.write_to("results/bench").expect("write results");

    let table4 = fig7_table4::run_table4(&coord, n, h, &cfg);
    table4.print();
    table4.write_to("results/bench").expect("write results");
}
