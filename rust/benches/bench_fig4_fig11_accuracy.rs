//! Regenerates **Figure 4** (exact vs interpolated factor-entry curves over
//! λ) and **Figure 11** (NRMSE of the interpolation vs λ).
//!
//! `cargo bench --bench bench_fig4_fig11_accuracy`

use picholesky::experiments::{fig11, fig4};

fn main() {
    // paper Figure 4 setting: 2nd-order polynomials from 6 sample λ's,
    // evaluated on a 50-point dense grid
    let f4 = fig4::run(128, 6, 2, 50, 0xF164);
    f4.print();
    f4.write_to("results/bench").expect("write results");

    // paper Figure 11 setting: g=4, r=2 over the 31-point grid
    let f11 = fig11::run(128, 4, 2, 31, 0xF111);
    f11.print();
    f11.write_to("results/bench").expect("write results");
}
