//! Integration tests over the native CV stack: coordinator scheduling,
//! config plumbing, and cross-algorithm agreement at realistic (small) sizes.

use std::sync::Arc;

use picholesky::config::{parse_toml, ExperimentConfig};
use picholesky::coordinator::Coordinator;
use picholesky::cv::solvers::SolverKind;
use picholesky::cv::{run_cv, CvConfig, Metric};
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};

fn small_cfg() -> CvConfig {
    CvConfig {
        k_folds: 3,
        q_grid: 13,
        ..CvConfig::default()
    }
}

#[test]
fn pichol_speedup_and_agreement_grows_with_h() {
    // the paper's central claim, end-to-end on the native path
    let cfg = small_cfg();
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 300, 128, 11);
    let chol = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
    let pi = run_cv(&ds, SolverKind::PiChol, &cfg).unwrap();

    // timing: piCholesky must beat exact Cholesky at h=128, q=13
    assert!(
        pi.total_secs() < chol.total_secs(),
        "pichol {:.3}s !< chol {:.3}s",
        pi.total_secs(),
        chol.total_secs()
    );
    // accuracy: best errors within 2%
    assert!(
        (pi.best_error - chol.best_error).abs() / chol.best_error < 0.02,
        "errors diverge: {} vs {}",
        pi.best_error,
        chol.best_error
    );
}

#[test]
fn all_seven_solvers_complete_on_all_datasets() {
    let cfg = CvConfig {
        k_folds: 2,
        q_grid: 7,
        ..CvConfig::default()
    };
    for kind in DatasetKind::all() {
        let ds = SyntheticDataset::generate(kind, 90, 13, 5);
        for solver in [
            SolverKind::Chol,
            SolverKind::PiChol,
            SolverKind::MChol,
            SolverKind::Svd,
            SolverKind::TSvd,
            SolverKind::RSvd,
            SolverKind::Pinrmse,
        ] {
            let rep = run_cv(&ds, solver, &cfg).unwrap();
            assert!(
                rep.best_error.is_finite(),
                "{} on {} produced {}",
                solver.name(),
                kind.name(),
                rep.best_error
            );
        }
    }
}

#[test]
fn misclass_metric_plumbs_through() {
    let cfg = CvConfig {
        k_folds: 2,
        q_grid: 7,
        metric: Metric::Misclass,
        ..CvConfig::default()
    };
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 120, 17, 6);
    let rep = run_cv(&ds, SolverKind::PiChol, &cfg).unwrap();
    // misclassification is a rate
    assert!(rep.best_error >= 0.0 && rep.best_error <= 1.0);
}

#[test]
fn parallel_sweep_matches_serial_k5_q50() {
    // acceptance bar: threads ≥ 2 over a k=5, q=50 grid matches the serial
    // path within 1e-12 on best_lambda / best_error (the engine actually
    // guarantees bit-identity; 1e-12 is the contractual bound)
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 200, 19, 13);
    for kind in [SolverKind::Chol, SolverKind::PiChol] {
        let serial_cfg = CvConfig {
            k_folds: 5,
            q_grid: 50,
            sweep_threads: 1,
            ..CvConfig::default()
        };
        let parallel_cfg = CvConfig {
            sweep_threads: 4,
            ..serial_cfg.clone()
        };
        let serial = run_cv(&ds, kind, &serial_cfg).unwrap();
        let parallel = run_cv(&ds, kind, &parallel_cfg).unwrap();
        assert!(
            (serial.best_lambda - parallel.best_lambda).abs() <= 1e-12,
            "{}: best_lambda {} vs {}",
            kind.name(),
            serial.best_lambda,
            parallel.best_lambda
        );
        assert!(
            (serial.best_error - parallel.best_error).abs() <= 1e-12,
            "{}: best_error {} vs {}",
            kind.name(),
            serial.best_error,
            parallel.best_error
        );
        for (a, b) in serial.mean_errors.iter().zip(&parallel.mean_errors) {
            assert_eq!(a, b, "mean error curves must be bit-identical");
        }
    }
}

#[test]
fn coordinator_pool_matches_sequential_results() {
    let cfg = small_cfg();
    let ds = Arc::new(SyntheticDataset::generate(DatasetKind::CoilLike, 150, 21, 7));
    let coord = Coordinator::new(3);
    let par = coord.run_matrix(ds.clone(), &[SolverKind::Chol, SolverKind::PiChol], &cfg);
    let seq_chol = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
    let par_chol = par.into_iter().next().unwrap().unwrap();
    // identical seeds ⇒ identical folds ⇒ identical errors
    assert_eq!(par_chol.mean_errors.len(), seq_chol.mean_errors.len());
    for (a, b) in par_chol.mean_errors.iter().zip(&seq_chol.mean_errors) {
        assert_eq!(a, b, "parallel and sequential runs must be bit-identical");
    }
}

#[test]
fn experiment_config_end_to_end() {
    let doc = parse_toml(
        r#"
        dataset = "coil"
        n = 90
        h = 13
        seed = 3
        [cv]
        k_folds = 2
        q_grid = 5
        "#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_doc(&doc).unwrap();
    let ds = SyntheticDataset::generate(cfg.dataset, cfg.n, cfg.h, cfg.seed);
    let rep = run_cv(&ds, SolverKind::PiChol, &cfg.cv).unwrap();
    assert_eq!(rep.grid.len(), 5);
}

#[test]
fn lambda_range_override_respected() {
    let mut cfg = small_cfg();
    cfg.lambda_range = Some((1e-2, 1e-1));
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 90, 13, 9);
    let rep = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
    assert!((rep.grid[0] - 1e-2).abs() < 1e-12);
    assert!((rep.grid.last().unwrap() - 1e-1).abs() < 1e-9);
}
