//! Per-backend kernel conformance — the bitwise pin between the scalar
//! micro-kernel oracle and every SIMD backend available on the host.
//!
//! The dispatch seam in `linalg::kernel` promises that AVX2/NEON micro-
//! kernels are drop-in replacements for the scalar loop down to the last
//! ulp: same ascending-k accumulation, same two roundings per term, lanes
//! mapped one-to-one onto register-tile columns. This file enforces that
//! promise end to end:
//!
//! - GEMM (plain, `AᵀB`, `ABᵀ`) and SYRK on degenerate shapes
//!   (`1, 2, MR−1, NR−1`) and cache-block edges (`KC±1`, `MC±1`), through
//!   the same transposed `Src` views the library uses;
//! - TRSM and the rank-k Cholesky downdate chain (the factor-level k-fold
//!   kernel);
//! - whole `run_cv` hold-out curves on the conformance-suite generators at
//!   workers {1, 2, 4};
//! - dispatch plumbing: a forced backend is the one `SweepReport` records,
//!   and the `PICHOL_KERNEL_BACKEND` env var steers a fresh process
//!   (unavailable names fall back to detection, never panic);
//! - the `fold_strategy = auto` picker: synthetic `BENCH_kernels.json`
//!   fixtures (via `PICHOL_BENCH_FILE`) drive it to either side of the
//!   crossover, and absent/malformed files degrade to the default.
//!
//! `ci.sh --backends` runs this file once per detected backend.

use std::sync::Mutex;

use picholesky::cv::solvers::SolverKind;
use picholesky::cv::strategy::{AUTO_DEFAULT, BENCH_FILE_ENV};
use picholesky::cv::{run_cv, CvConfig, FoldStrategy};
use picholesky::linalg::kernel::{KC, MC, MR, NR};
use picholesky::linalg::{
    available_backends, cholesky_blocked, downdate_rank_k, force_backend, syrk_lower,
    trsm_left_lower, Gemm, KernelBackend, Matrix,
};
use picholesky::testutil::{conformance::suite, random_matrix};

/// Backend forcing and env-var mutation are process-global; every test that
/// touches either serializes on this lock (poisoning is ignored — a failed
/// test must not cascade into spurious lock panics).
static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits(m: &Matrix) -> Vec<u64> {
    m.as_slice().iter().map(|v| v.to_bits()).collect()
}

/// Run `op` under the scalar oracle, then under every other available
/// backend, asserting the returned bits are identical. Restores the
/// detected backend afterwards.
fn assert_backends_bitwise<F: FnMut(KernelBackend) -> Vec<u64>>(what: &str, mut op: F) {
    let _g = lock();
    force_backend(KernelBackend::Scalar).unwrap();
    let oracle = op(KernelBackend::Scalar);
    for be in available_backends() {
        if be == KernelBackend::Scalar {
            continue;
        }
        force_backend(be).unwrap();
        let got = op(be);
        assert_eq!(oracle.len(), got.len(), "{what}: length drifted on {}", be.name());
        for (i, (a, b)) in oracle.iter().zip(&got).enumerate() {
            assert_eq!(
                a,
                b,
                "{what}: backend {} diverges from scalar at flat index {i}: {:e} vs {:e}",
                be.name(),
                f64::from_bits(*a),
                f64::from_bits(*b)
            );
        }
    }
    force_backend(KernelBackend::detect()).unwrap();
}

/// GEMM in all three transpose configurations plus SYRK, on every
/// combination of the degenerate dimensions the register tile can mis-handle
/// (`1`, `2`, `MR−1`, `NR−1`) — zero-padded tails, single slivers, tiles
/// narrower than one vector lane group.
#[test]
fn gemm_family_bitwise_on_degenerate_shapes() {
    let dims = [1usize, 2, MR - 1, NR - 1];
    let gem = Gemm::default();
    for &m in &dims {
        for &k in &dims {
            for &n in &dims {
                let seed = (m * 289 + k * 17 + n) as u64;
                let a = random_matrix(m, k, seed);
                let b = random_matrix(k, n, seed + 1);
                let bt = random_matrix(n, k, seed + 2);
                let at = random_matrix(k, m, seed + 3);
                assert_backends_bitwise(&format!("mul {m}x{k}x{n}"), |_| bits(&gem.mul(&a, &b)));
                assert_backends_bitwise(&format!("at_b {m}x{k}x{n}"), |_| {
                    bits(&gem.at_b(&at, &b))
                });
                assert_backends_bitwise(&format!("a_bt {m}x{k}x{n}"), |_| {
                    bits(&gem.a_bt(&a, &bt))
                });
                assert_backends_bitwise(&format!("syrk {k}x{n}"), |_| {
                    bits(&syrk_lower(&random_matrix(k, n, seed + 4)))
                });
            }
        }
    }
}

/// The same family across the cache-block edges: shapes straddling `KC±1`
/// and `MC±1` exercise the absolute-index k-chunking and the packed-panel
/// boundaries, where a backend with different chunk handling would first
/// diverge.
#[test]
fn gemm_family_bitwise_on_cache_block_edges() {
    let gem = Gemm::default();
    for &(m, k, n) in &[
        (MC + 1, KC + 1, NR + 1),
        (MC - 1, KC - 1, 2 * NR + 3),
        (5, KC + 1, 9),
        (MC + 1, 7, 33),
    ] {
        let seed = (m * 1009 + k * 31 + n) as u64;
        let a = random_matrix(m, k, seed);
        let b = random_matrix(k, n, seed + 1);
        let bt = random_matrix(n, k, seed + 2);
        assert_backends_bitwise(&format!("edge mul {m}x{k}x{n}"), |_| bits(&gem.mul(&a, &b)));
        assert_backends_bitwise(&format!("edge a_bt {m}x{k}x{n}"), |_| {
            bits(&gem.a_bt(&a, &bt))
        });
    }
    // SYRK at a k extent crossing KC (its Src::T/Src::N band views with
    // row/col offsets are the transposed-view stress case)
    let x = random_matrix(KC + 3, 2 * NR + 1, 99);
    assert_backends_bitwise("edge syrk", |_| bits(&syrk_lower(&x)));
}

/// TRSM and the rank-k hyperbolic downdate chain: both consume factors the
/// packed engine produced, so the whole pipeline — Gram, Cholesky, solve,
/// fold downdate — must come out bit-identical per backend.
#[test]
fn trsm_and_downdate_chain_bitwise() {
    let (n, d, nv) = (40usize, 17usize, 6usize);
    let x = random_matrix(n, d, 7);
    let rhs = random_matrix(d, 5, 8);
    assert_backends_bitwise("chol+trsm+downdate", |_| {
        // chol(G + I): the shared anchor of the factor-level k-fold engine
        let mut g = syrk_lower(&x);
        for i in 0..d {
            g[(i, i)] += 1.0;
        }
        let l = cholesky_blocked(&g).expect("G + I is SPD");
        let sol = trsm_left_lower(&l, &rhs);
        // fold downdate: G + I − X_vᵀX_v = X_tᵀX_t + I stays SPD
        let xv = x.slice(0, nv, 0, d);
        let (mut out, mut ubuf, mut trans) =
            (Matrix::zeros(0, 0), Matrix::zeros(0, 0), Matrix::zeros(0, 0));
        downdate_rank_k(&l, &xv, &mut out, &mut ubuf, &mut trans)
            .expect("train Gram stays SPD");
        let mut all = bits(&l);
        all.extend(bits(&sol));
        all.extend(bits(&out));
        all
    });
}

/// Whole-pipeline conformance: `run_cv` hold-out curves on every
/// conformance-suite generator, at workers {1, 2, 4}, are bit-identical
/// across backends — the engine's thread-count determinism contract and the
/// backend interchange contract composed.
#[test]
fn run_cv_curves_bitwise_across_backends_and_workers() {
    for (name, ds) in suite(100, 12, 11) {
        for workers in [1usize, 2, 4] {
            let cfg = CvConfig {
                k_folds: 4,
                q_grid: 9,
                lambda_range: Some((1e-2, 1.0)),
                sweep_threads: workers,
                fold_strategy: FoldStrategy::Downdate,
                ..CvConfig::default()
            };
            assert_backends_bitwise(&format!("run_cv {name} workers={workers}"), |_| {
                let rep = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
                let mut all: Vec<u64> = rep.mean_errors.iter().map(|v| v.to_bits()).collect();
                all.push(rep.best_lambda.to_bits());
                all.push(rep.best_error.to_bits());
                for (l, e) in &rep.fold_bests {
                    all.push(l.to_bits());
                    all.push(e.to_bits());
                }
                all
            });
        }
    }
}

/// Dispatch plumbing: the forced backend is the one the report records.
#[test]
fn forced_backend_is_reported_in_sweep_report() {
    let _g = lock();
    let (_, ds) = suite(60, 8, 3).into_iter().next().unwrap();
    let cfg = CvConfig {
        k_folds: 3,
        q_grid: 5,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: 1,
        ..CvConfig::default()
    };
    for be in available_backends() {
        force_backend(be).unwrap();
        let rep = run_cv(&ds, SolverKind::Chol, &cfg).unwrap();
        assert_eq!(rep.kernel_backend, be.name(), "report must name the active backend");
    }
    force_backend(KernelBackend::detect()).unwrap();
}

/// Env-var dispatch, observed from a fresh process (the in-process cache
/// resolves once, so only a child can see first-use behavior): forcing
/// `scalar` is honored; an unavailable/garbage name falls back to detection
/// without failing the run.
#[test]
fn env_var_steers_backend_in_fresh_process() {
    let run = |env_val: &str| {
        std::process::Command::new(env!("CARGO_BIN_EXE_pichol"))
            .args([
                "cv", "--dataset", "mnist", "--h", "8", "--n", "40", "--folds", "3", "--grid",
                "5", "--threads", "1", "--seed", "1", "--solver", "chol",
            ])
            .env("PICHOL_KERNEL_BACKEND", env_val)
            .output()
            .expect("spawn pichol")
    };

    let out = run("scalar");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "scalar run failed: {stdout}");
    assert!(
        stdout.contains("kernel_backend=scalar"),
        "PICHOL_KERNEL_BACKEND=scalar not honored:\n{stdout}"
    );

    // garbage name: detection kicks in, the run still succeeds and reports
    // some real backend
    let out = run("not-a-backend");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "fallback run failed: {stdout}");
    assert!(
        ["scalar", "avx2", "neon"]
            .iter()
            .any(|b| stdout.contains(&format!("kernel_backend={b}"))),
        "no backend reported under garbage env:\n{stdout}"
    );
}

/// Write `text` to a unique temp file and return its path.
fn fixture(tag: &str, text: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!(
        "pichol_bench_fixture_{}_{tag}.json",
        std::process::id()
    ));
    std::fs::write(&p, text).unwrap();
    p
}

fn bench_json(packed: f64, reference: f64, d: usize) -> String {
    format!(
        r#"{{"bench": "kernels", "kernel_backend": "scalar", "results": [
            {{"kernel": "gemm", "d": {d}, "packed_secs": 1.0, "reference_secs": 2.0}},
            {{"kernel": "chud_rk", "d": {d}, "packed_secs": {packed}, "reference_secs": {reference}}}
        ]}}"#
    )
}

fn auto_cfg() -> CvConfig {
    CvConfig {
        k_folds: 3,
        q_grid: 5,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: 1,
        fold_strategy: FoldStrategy::Auto,
        ..CvConfig::default()
    }
}

/// Synthetic bench fixtures drive the auto picker to either side of the
/// crossover, and the report records the measured provenance.
#[test]
fn auto_strategy_follows_bench_fixture_to_either_side() {
    let _g = lock();
    let (_, ds) = suite(60, 8, 3).into_iter().next().unwrap();

    // downdate chains measured far cheaper than refactorization
    let cheap = fixture("cheap", &bench_json(1e-6, 1.0, 8));
    std::env::set_var(BENCH_FILE_ENV, &cheap);
    let rep = run_cv(&ds, SolverKind::Chol, &auto_cfg()).unwrap();
    assert_eq!(rep.fold_strategy, FoldStrategy::Downdate);
    assert_eq!(rep.strategy_source, "bench-file");

    // downdate chains measured absurdly expensive
    let dear = fixture("dear", &bench_json(1.0, 1e-6, 8));
    std::env::set_var(BENCH_FILE_ENV, &dear);
    let rep = run_cv(&ds, SolverKind::Chol, &auto_cfg()).unwrap();
    assert_eq!(rep.fold_strategy, FoldStrategy::Refactor);
    assert_eq!(rep.strategy_source, "bench-file");

    std::env::remove_var(BENCH_FILE_ENV);
    let _ = std::fs::remove_file(cheap);
    let _ = std::fs::remove_file(dear);
}

/// Absent or malformed bench files degrade to the compiled-in default —
/// recorded as such, never a panic.
#[test]
fn auto_strategy_survives_missing_and_malformed_bench_files() {
    let _g = lock();
    let (_, ds) = suite(60, 8, 3).into_iter().next().unwrap();

    let missing = std::env::temp_dir().join(format!(
        "pichol_bench_fixture_{}_does_not_exist.json",
        std::process::id()
    ));
    let garbage = fixture("garbage", "not json at all {{{");
    let wrong_shape = fixture("wrong_shape", r#"{"rows": "no rows here"}"#);

    for p in [&missing, &garbage, &wrong_shape] {
        std::env::set_var(BENCH_FILE_ENV, p);
        let rep = run_cv(&ds, SolverKind::Chol, &auto_cfg()).unwrap();
        assert_eq!(
            rep.fold_strategy,
            AUTO_DEFAULT,
            "default must apply for {}",
            p.display()
        );
        assert_eq!(rep.strategy_source, "default");
    }

    std::env::remove_var(BENCH_FILE_ENV);
    let _ = std::fs::remove_file(garbage);
    let _ = std::fs::remove_file(wrong_shape);
}
