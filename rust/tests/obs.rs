//! Observability acceptance suite (`ci.sh --obs`).
//!
//! The contract under test ([`picholesky::obs`]):
//!
//! - **No perturbation** — arming the event/histogram layer changes no
//!   numeric output bitwise, for every CV tier (k-fold, exact LOO,
//!   ALOOCV) at workers {1, 2, 4}.
//! - **Deterministic content** — the merged event log's content tuples
//!   `(task_id, attempt, kind, surface, fold, λ-index, outcome,
//!   degradations)` are identical at every worker count. Wall times and
//!   worker ids are payload, not contract: task ids are allocated on the
//!   coordinating thread at job-construction time and the post-run merge
//!   sorts by `(task_id, attempt)`.
//! - **Mergeable histograms** — bucket contents and quantiles are
//!   invariant under any partition of the samples across any number of
//!   per-worker histograms and any merge order.
//! - **Ledger** — the JSONL render carries resolved-config provenance,
//!   one record per degradation, and p50/p90/p99 per phase and per task
//!   kind.
//!
//! `ci.sh --obs` runs exactly this file, then exercises the CLI artifact
//! paths (`--trace-out` / `--ledger-out`) end to end.

use picholesky::cv::aloocv::run_aloocv;
use picholesky::cv::loo::run_loo;
use picholesky::cv::solvers::SolverKind;
use picholesky::cv::{run_cv, CvConfig, FoldStrategy};
use picholesky::obs::hist::Hist;
use picholesky::obs::ledger::{render_ledger, LedgerRun};
use picholesky::obs::ObsReport;
use picholesky::testutil::conformance::well_conditioned;
use picholesky::testutil::{faults, proptest_lite};

/// Pinned execution shape: the default `sweep_batch` is derived from the
/// thread count, so worker-count-invariance assertions must fix it.
fn cfg(workers: usize, obs: bool) -> CvConfig {
    CvConfig {
        k_folds: 3,
        q_grid: 10,
        g_samples: 4,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: workers,
        sweep_batch: 4,
        fold_strategy: FoldStrategy::Downdate,
        obs,
        ..CvConfig::default()
    }
}

/// The full per-event content tuple — everything that is contract, nothing
/// that is payload (no wall times, no worker ids).
type Content = (u32, u32, &'static str, &'static str, i64, i64, &'static str, u32);

fn content(o: &ObsReport) -> Vec<Content> {
    o.events
        .iter()
        .map(|e| {
            (
                e.task_id,
                e.attempt,
                e.kind,
                e.surface,
                e.fold,
                e.lambda_index,
                e.outcome.name(),
                e.degradations,
            )
        })
        .collect()
}

fn assert_strictly_ordered(o: &ObsReport) {
    for w in o.events.windows(2) {
        assert!(
            (w[0].task_id, w[0].attempt) < (w[1].task_id, w[1].attempt),
            "merged log must be strictly ascending in (task_id, attempt): \
             ({}, {}) then ({}, {})",
            w[0].task_id,
            w[0].attempt,
            w[1].task_id,
            w[1].attempt
        );
    }
}

/// Satellite: histogram merging is a commutative monoid action — any
/// partition of the samples across {1, 2, 4} worker-local histograms,
/// merged in any order, reproduces the monolithic histogram bit for bit
/// (bucket counts are exact integers, so equality is exact).
#[test]
fn hist_merge_is_partition_and_order_invariant() {
    proptest_lite::check("hist-merge", 40, |case| {
        let count = case.dim(0, 300);
        let samples: Vec<u64> = (0..count)
            .map(|_| {
                // span the full bucket range: magnitudes from 2⁰ to 2⁴⁰
                let mag = case.dim(0, 40);
                case.rng.below(1u64 << mag)
            })
            .collect();
        let mut whole = Hist::new();
        for &v in &samples {
            whole.record(v);
        }
        for &w in &[1usize, 2, 4] {
            let mut parts = vec![Hist::new(); w];
            for &v in &samples {
                let i = case.rng.below(w as u64) as usize;
                parts[i].record(v);
            }
            let mut fwd = Hist::new();
            for p in &parts {
                fwd.merge(p);
            }
            let mut rev = Hist::new();
            for p in parts.iter().rev() {
                rev.merge(p);
            }
            assert_eq!(fwd, whole, "forward merge of {w} parts");
            assert_eq!(rev, whole, "reverse merge of {w} parts");
            for &q in &[0.5, 0.9, 0.99] {
                assert_eq!(fwd.quantile(q), whole.quantile(q));
                assert_eq!(rev.quantile(q), whole.quantile(q));
            }
        }
    });
}

/// Satellite edge case: quantiles of an empty histogram are `None`, not a
/// default bucket — and merging empties never fabricates samples.
#[test]
fn empty_histogram_quantiles_are_none() {
    let h = Hist::new();
    assert!(h.is_empty());
    assert_eq!(h.count(), 0);
    assert_eq!(h.quantile(0.5), None);
    assert_eq!(h.quantile(0.99), None);
    assert_eq!(h.quantile_us(0.5), None);
    let mut m = Hist::new();
    m.merge(&h);
    assert!(m.is_empty(), "merging empties must stay empty");
    assert_eq!(m.quantile(0.5), None);
}

/// Off by default: no flag, no payload — for every tier.
#[test]
fn obs_is_off_by_default() {
    let ds = well_conditioned(40, 8, 5);
    assert!(run_cv(&ds, SolverKind::Chol, &cfg(2, false)).unwrap().obs.is_none());
    assert!(run_loo(&ds, &cfg(2, false)).unwrap().obs.is_none());
    assert!(run_aloocv(&ds, &cfg(2, false)).unwrap().obs.is_none());
}

/// Acceptance (k-fold): arming obs changes nothing numeric, and the event
/// content is identical at workers {1, 2, 4}.
#[test]
fn kfold_obs_never_perturbs_and_content_is_worker_invariant() {
    let ds = well_conditioned(60, 9, 3);
    let mut logs = Vec::new();
    for workers in [1usize, 2, 4] {
        let off = run_cv(&ds, SolverKind::Chol, &cfg(workers, false)).unwrap();
        let on = run_cv(&ds, SolverKind::Chol, &cfg(workers, true)).unwrap();
        assert!(off.obs.is_none());
        let obs = on.obs.as_ref().expect("armed run must carry a payload");
        assert_eq!(off.mean_errors, on.mean_errors, "workers={workers}");
        assert_eq!(off.fold_bests, on.fold_bests, "workers={workers}");
        assert_eq!(off.best_lambda, on.best_lambda);
        assert_eq!(off.best_error, on.best_error);
        assert_eq!(obs.dropped, 0, "rings must be sized for the whole run");
        assert!(!obs.events.is_empty());
        assert_strictly_ordered(obs);
        logs.push(content(obs));
    }
    assert_eq!(logs[0], logs[1], "event content must not depend on workers");
    assert_eq!(logs[0], logs[2], "event content must not depend on workers");
}

/// Acceptance (exact LOO): same no-perturbation + invariance contract.
#[test]
fn loo_obs_never_perturbs_and_content_is_worker_invariant() {
    let ds = well_conditioned(50, 8, 7);
    let mut logs = Vec::new();
    for workers in [1usize, 2, 4] {
        let off = run_loo(&ds, &cfg(workers, false)).unwrap();
        let on = run_loo(&ds, &cfg(workers, true)).unwrap();
        assert!(off.obs.is_none());
        let obs = on.obs.as_ref().expect("armed run must carry a payload");
        assert_eq!(off.curve, on.curve, "workers={workers}");
        assert_eq!(off.anchor_rmse, on.anchor_rmse, "workers={workers}");
        assert_eq!(off.best_lambda, on.best_lambda);
        assert_eq!(off.best_error, on.best_error);
        assert_eq!(obs.dropped, 0);
        assert_strictly_ordered(obs);
        logs.push(content(obs));
    }
    assert_eq!(logs[0], logs[1]);
    assert_eq!(logs[0], logs[2]);
}

/// Acceptance (ALOOCV): same no-perturbation + invariance contract.
#[test]
fn aloocv_obs_never_perturbs_and_content_is_worker_invariant() {
    let ds = well_conditioned(50, 8, 7);
    let mut logs = Vec::new();
    for workers in [1usize, 2, 4] {
        let off = run_aloocv(&ds, &cfg(workers, false)).unwrap();
        let on = run_aloocv(&ds, &cfg(workers, true)).unwrap();
        assert!(off.obs.is_none());
        let obs = on.obs.as_ref().expect("armed run must carry a payload");
        assert_eq!(off.curve, on.curve, "workers={workers}");
        assert_eq!(off.anchor_rmse, on.anchor_rmse, "workers={workers}");
        assert_eq!(off.best_lambda, on.best_lambda);
        assert_eq!(off.best_error, on.best_error);
        assert_eq!(obs.dropped, 0);
        assert_strictly_ordered(obs);
        logs.push(content(obs));
    }
    assert_eq!(logs[0], logs[1]);
    assert_eq!(logs[0], logs[2]);
}

/// The armed run feeds the per-phase histograms: every phase the timer
/// accumulated has a histogram with the same invocation count, and the
/// per-kind histograms cover every event.
#[test]
fn armed_run_populates_phase_and_kind_histograms() {
    let ds = well_conditioned(60, 9, 3);
    let rep = run_cv(&ds, SolverKind::Chol, &cfg(2, true)).unwrap();
    let obs = rep.obs.as_ref().unwrap();
    assert!(!obs.phase_hists.is_empty());
    for (name, _) in rep.timer.entries() {
        let h = obs
            .phase_hists
            .get(name)
            .unwrap_or_else(|| panic!("phase '{name}' missing a histogram"));
        assert_eq!(
            h.count(),
            rep.timer.count(name),
            "phase '{name}': histogram samples must equal timer invocations"
        );
    }
    let kind_total: u64 = obs.kind_hists.entries().iter().map(|(_, h)| h.count()).sum();
    assert_eq!(
        kind_total,
        obs.events.len() as u64,
        "every event lands in exactly one per-kind histogram"
    );
}

/// Ledger acceptance: a degraded run renders resolved-config provenance,
/// one record per degradation, and per-phase/per-kind quantiles — every
/// line a JSON object.
#[test]
fn ledger_records_provenance_degradations_and_quantiles() {
    let mut ds = well_conditioned(40, 8, 5);
    faults::spike_row(&mut ds, 0);
    let c = cfg(2, true);
    let rep = run_cv(&ds, SolverKind::Chol, &c).unwrap();
    assert!(!rep.degradations.is_empty(), "the spike must climb the ladder");
    let obs = rep.obs.as_ref().unwrap();
    assert!(
        obs.events.iter().any(|e| e.degradations > 0),
        "degraded cells must be visible in the event log"
    );
    let run = LedgerRun {
        mode: "kfold",
        solver: "chol",
        kernel_backend: rep.kernel_backend,
        fold_strategy: rep.fold_strategy.name(),
        strategy_source: rep.strategy_source,
        threads: rep.threads,
        tasks: rep.tasks,
        k_folds: c.k_folds,
        q_grid: c.q_grid,
        g_samples: c.g_samples,
        seed: c.seed,
        policy: &c.recovery,
        best_lambda: rep.best_lambda,
        best_error: rep.best_error,
        wall_secs: rep.wall_secs,
        degradations: &rep.degradations,
        certification: None,
        timer: &rep.timer,
        obs,
    };
    let s = render_ledger(&run);
    for line in s.lines() {
        assert!(
            line.starts_with('{') && line.ends_with('}'),
            "every ledger line must be one JSON object: {line}"
        );
    }
    assert!(s.contains("\"record\":\"provenance\""));
    assert!(s.contains("\"strategy_source\":"));
    assert!(s.contains("\"kernel_backend\":"));
    assert_eq!(
        s.matches("\"record\":\"degradation\"").count(),
        rep.degradations.len(),
        "one ledger record per degradation"
    );
    assert!(s.contains("\"record\":\"phase\""));
    assert!(s.contains("\"p50_us\"") && s.contains("\"p90_us\"") && s.contains("\"p99_us\""));
    assert!(s.contains("\"record\":\"task_kind\""));
    assert!(s.contains("\"record\":\"summary\""));
}

/// The Chrome exporter writes one complete-span object per event into a
/// single JSON array (the shape chrome://tracing and Perfetto load).
#[test]
fn chrome_trace_export_covers_every_event() {
    let ds = well_conditioned(40, 8, 5);
    let rep = run_cv(&ds, SolverKind::Chol, &cfg(2, true)).unwrap();
    let obs = rep.obs.as_ref().unwrap();
    let path = std::env::temp_dir().join(format!("pichol_trace_{}.json", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    picholesky::obs::trace::write_chrome_trace(&path_s, &obs.events).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(text.trim_start().starts_with('['));
    assert!(text.trim_end().ends_with(']'));
    assert_eq!(
        text.matches("\"ph\":\"X\"").count(),
        obs.events.len(),
        "one complete-span record per event"
    );
    assert!(text.contains("\"args\":{\"task_id\":"));
}
