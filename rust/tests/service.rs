//! Determinism and liveness suite for the streaming CV service
//! (`coordinator::service` + `cv::window`), pinning the ISSUE-10
//! acceptance bar:
//!
//! - the same admitted row sequence yields **bitwise-identical** final
//!   snapshots and **identical** degradation ledgers at any eval worker
//!   count and any admission batch size;
//! - the window's segment-partial refold round-trips **bitwise** against
//!   a from-scratch `GramCache` over the surviving rows;
//! - queries never block on a window update, and served epochs are
//!   monotone;
//! - arming observability perturbs no numeric bit and reports the
//!   admit/refresh/query span log plus the latency histograms.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use picholesky::coordinator::service::{run_replay, CvService, ReplayConfig, ServiceReport};
use picholesky::cv::window::{ServiceConfig, WindowCv};
use picholesky::cv::CvConfig;
use picholesky::data::gram::{GramCache, SEGMENT_ROWS};
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};

fn cv_cfg() -> CvConfig {
    CvConfig {
        q_grid: 9,
        g_samples: 4,
        lambda_range: Some((0.1, 10.0)),
        ..CvConfig::default()
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn replay(workers: usize, batch: usize) -> ServiceReport {
    let svc = ServiceConfig {
        window: 2 * SEGMENT_ROWS,
        refresh_every: 48,
        workers,
        ..ServiceConfig::default()
    };
    let mut cv = cv_cfg();
    // a hop budget the stream trips repeatedly: every trip re-anchors λ*
    // through the recovery ladder and records a degradation — so the
    // ledger-identity assertion below is not vacuous
    cv.recovery.budget.max_hops = 40;
    let replay = ReplayConfig {
        rows: 600,
        dim: 8,
        batch,
        queries_per_batch: 2,
        kind: DatasetKind::MnistLike,
        seed: 11,
    };
    run_replay(replay, svc, cv)
}

/// The tentpole acceptance test: one seeded traffic replay, re-run across
/// eval worker counts {1,2,4} × admission batch sizes {1,3,64}. Refresh
/// points are a pure function of the admitted row sequence and the eval
/// fan-out merges in input order, so every run must land on the same
/// snapshot **bits** and the same degradation ledger.
#[test]
fn replay_is_bitwise_invariant_across_workers_and_batches() {
    let base = replay(1, 1);
    assert_eq!(base.rows_admitted, 600);
    assert!(base.refreshes > 1, "the stream must refresh repeatedly");
    assert!(base.final_snapshot.best_lambda.is_finite());
    assert!(
        !base.degradations.is_empty(),
        "the hop budget must have tripped re-anchors"
    );
    let base_degs: Vec<String> = base.degradations.iter().map(|d| d.to_string()).collect();
    for workers in [1usize, 2, 4] {
        for batch in [1usize, 3, 64] {
            if (workers, batch) == (1, 1) {
                continue;
            }
            let rep = replay(workers, batch);
            let tag = format!("workers={workers} batch={batch}");
            let (a, b) = (&base.final_snapshot, &rep.final_snapshot);
            assert_eq!(rep.rows_admitted, 600, "{tag}");
            assert_eq!(base.refreshes, rep.refreshes, "{tag}: refresh points drifted");
            assert_eq!(a.epoch, b.epoch, "{tag}");
            assert_eq!(a.rows, b.rows, "{tag}");
            assert_eq!(bits(&a.curve), bits(&b.curve), "{tag}: curve bits");
            assert_eq!(bits(&a.anchor_rmse), bits(&b.anchor_rmse), "{tag}: anchor bits");
            assert_eq!(bits(&a.theta), bits(&b.theta), "{tag}: θ(λ*) bits");
            assert_eq!(a.best_lambda.to_bits(), b.best_lambda.to_bits(), "{tag}");
            assert_eq!(a.best_error.to_bits(), b.best_error.to_bits(), "{tag}");
            let degs: Vec<String> = rep.degradations.iter().map(|d| d.to_string()).collect();
            assert_eq!(base_degs, degs, "{tag}: degradation ledgers diverged");
        }
    }
}

/// The window's retire/append round-trip: after streaming well past
/// capacity (sealed-segment retirements included), the segment-partial
/// refold equals a from-scratch `GramCache::assemble` over exactly the
/// surviving rows, bit for bit.
#[test]
fn window_round_trip_matches_from_scratch_gram_bitwise() {
    let n = 3 * SEGMENT_ROWS + 10;
    let ds = SyntheticDataset::generate(DatasetKind::CoilLike, n, 9, 123);
    let svc = ServiceConfig {
        window: 2 * SEGMENT_ROWS,
        ..ServiceConfig::default()
    };
    let mut win = WindowCv::new(svc, cv_cfg());
    for i in 0..n {
        win.push_row(ds.x.row(i), ds.y[i]).unwrap();
    }
    assert!(win.rows() <= 2 * SEGMENT_ROWS, "retention must bound the window");
    let (wx, wy) = win.window_rows();
    let refold = win.refold();
    let fresh = GramCache::assemble(&wx, &wy);
    assert_eq!(refold.hessian().as_slice(), fresh.hessian().as_slice());
    assert_eq!(refold.gradient(), fresh.gradient());
    assert_eq!(refold.n_rows(), win.rows());
}

/// Snapshot serving is non-blocking: a reader thread hammering `query()`
/// while the worker admits and refreshes always gets a consistent
/// snapshot, and the epochs it observes are monotone. The reader holds a
/// `ServiceHandle` clone, so it must be joined (dropping its sender)
/// before `finish()` can drain the queue.
#[test]
fn queries_never_block_and_epochs_are_monotone() {
    let svc = ServiceConfig {
        window: 2 * SEGMENT_ROWS,
        refresh_every: 16,
        queue_depth: 4,
        workers: 2,
        ..ServiceConfig::default()
    };
    let (service, handle) = CvService::start(svc, cv_cfg());
    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let h = handle.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut last = 0u64;
            let mut queries = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let snap = h.query();
                assert!(snap.epoch >= last, "served epochs must be monotone");
                assert_eq!(
                    snap.curve.len(),
                    snap.grid.len(),
                    "a held snapshot is internally consistent at its epoch"
                );
                last = snap.epoch;
                queries += 1;
            }
            (last, queries)
        })
    };
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, 200, 6, 9);
    for lo in (0..200).step_by(5) {
        let hi = (lo + 5).min(200);
        handle
            .admit(ds.x.slice(lo, hi, 0, 6), ds.y[lo..hi].to_vec())
            .unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let (last_epoch, queries) = reader.join().unwrap();
    assert!(queries > 0, "the reader must have been served while admitting");
    drop(handle);
    let rep = service.finish();
    assert!(rep.refreshes >= 1);
    assert_eq!(rep.final_snapshot.epoch, rep.refreshes, "one epoch per refresh");
    assert!(last_epoch <= rep.final_snapshot.epoch);
    assert_eq!(rep.rows_admitted, 200);
    assert_eq!(rep.query_hist.count(), queries, "every query lands in the histogram");
}

/// Arming observability reports the admit/refresh/query span log and the
/// latency histograms — and perturbs not one numeric bit relative to the
/// same replay disarmed.
#[test]
fn armed_replay_reports_spans_without_perturbing_bits() {
    let svc = ServiceConfig {
        window: 2 * SEGMENT_ROWS,
        refresh_every: 32,
        ..ServiceConfig::default()
    };
    let replay_cfg = ReplayConfig {
        rows: 128,
        dim: 6,
        batch: 8,
        queries_per_batch: 3,
        kind: DatasetKind::MnistLike,
        seed: 5,
    };
    let dark = run_replay(replay_cfg, svc, cv_cfg());
    assert!(dark.obs.is_none(), "disarmed runs carry no obs payload");
    let mut cv = cv_cfg();
    cv.obs = true;
    let armed = run_replay(replay_cfg, svc, cv);
    let obs = armed.obs.as_ref().expect("armed run must carry an obs report");

    let kinds: Vec<&str> = obs.events.iter().map(|e| e.kind).collect();
    let count = |k: &str| kinds.iter().filter(|x| **x == k).count() as u64;
    assert_eq!(count("admit"), armed.batches, "one admit span per batch");
    assert_eq!(count("refresh"), armed.refreshes, "one refresh span per refresh");
    assert_eq!(
        count("query"),
        armed.query_hist.count(),
        "client query spans are appended at finish"
    );
    assert_eq!(armed.admit_hist.count(), armed.batches);
    assert!(!obs.phase_hists.is_empty(), "refresh phases land in hists");

    let (a, b) = (&dark.final_snapshot, &armed.final_snapshot);
    assert_eq!(bits(&a.curve), bits(&b.curve), "obs must not perturb curve bits");
    assert_eq!(bits(&a.theta), bits(&b.theta), "obs must not perturb θ bits");
    assert_eq!(a.best_lambda.to_bits(), b.best_lambda.to_bits());
}
