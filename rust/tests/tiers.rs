//! The accuracy/cost-ladder certification suite — the acceptance harness
//! of the ALOOCV tier (`ci.sh --tiers`).
//!
//! The ladder orders three ways to price a held-out row, cheapest first:
//!
//! - `aloocv` — hat-diagonal closed form, one batched multi-RHS TRSM per
//!   (batch, anchor): `O(n·d²)` per anchor for the whole dataset;
//! - `loo` — exact leave-one-out through rank-1 factor downdates:
//!   `O(n·d²)` **per row**;
//! - brute — per-row refactorization, `O(n·d³)` (bench baseline only).
//!
//! For ridge the hat-diagonal identity is exact, so the cheap tier is held
//! to *agreement*, not resemblance: anchor-by-anchor RMSE equality with the
//! exact tier within rounding, λ* selection certified within a decade
//! ([`run_certified`] stamps the verdict), bitwise invariance across worker
//! counts and batch sizes, and high-leverage rows (`h_i ≥ 1 − ε`) escalated
//! through the shared recovery ladder as recorded degradations — never
//! Inf/NaN cells.
//!
//! `ci.sh --tiers` runs exactly this file; the full CI gate includes it.

use picholesky::cv::aloocv::{run_aloocv, run_certified};
use picholesky::cv::loo::run_loo;
use picholesky::cv::recovery::Rung;
use picholesky::cv::CvConfig;
use picholesky::testutil::conformance::{
    assert_close_rms, spiked_dataset, suite, well_conditioned,
};

fn cfg(workers: usize) -> CvConfig {
    CvConfig {
        q_grid: 21,
        g_samples: 6,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: workers,
        ..CvConfig::default()
    }
}

/// The identity check: on a well-conditioned problem the cheap tier must
/// reproduce the exact tier's per-anchor RMSE to rounding (the closed form
/// is exact for ridge — only the arithmetic route differs), with the
/// structural phase counts proving it never touched per-row factor work.
#[test]
fn aloocv_matches_exact_loo_at_every_anchor() {
    let ds = well_conditioned(150, 16, 11);
    let aloo = run_aloocv(&ds, &cfg(2)).unwrap();
    let loo = run_loo(&ds, &cfg(2)).unwrap();

    assert_eq!(aloo.anchor_lambdas, loo.anchor_lambdas, "same plan, same anchors");
    assert!(aloo.skipped.is_empty() && loo.skipped.is_empty());
    assert!(
        aloo.degradations.is_empty(),
        "clean problem must stay on the fast path: {:?}",
        aloo.degradations
    );
    assert_close_rms(&aloo.anchor_rmse, &loo.anchor_rmse, 1e-8);
    // the interpolated curve passes the anchor values through the PINRMSE
    // polynomial fit, which can amplify anchor-level rounding by the fit's
    // conditioning — held to a correspondingly looser bound
    assert_close_rms(&aloo.curve, &loo.curve, 1e-6);

    // the cost structure, as phase counts: O(d³) only once per anchor, one
    // full-data solve per anchor, hat solves in whole per-anchor batch
    // waves, and zero per-row factorizations or downdates
    let g = aloo.anchor_lambdas.len() as u64;
    assert_eq!(aloo.timer.count("factor"), g);
    assert_eq!(aloo.timer.count("solve"), g);
    let hat = aloo.timer.count("hat_solve");
    assert!(hat >= g, "at least one batched hat solve per anchor");
    assert_eq!(hat % g, 0, "hat solves come in per-anchor batches");
    assert_eq!(aloo.timer.count("chol"), 0, "no per-row O(d³) factorization");
    assert_eq!(aloo.timer.count("downdate"), 0, "no per-row downdates");
}

/// The certification contract on every conformance generator: the tier
/// pair runs the same plan and the selected λ* must agree within one
/// decade — the verdict is stamped into the report, and a plain
/// (uncertified) run carries none.
#[test]
fn certification_passes_on_every_generator() {
    for (name, ds) in suite(150, 16, 11) {
        let rep = run_certified(&ds, &cfg(2)).unwrap();
        let cert = rep
            .certification
            .as_ref()
            .unwrap_or_else(|| panic!("{name}: certified run must stamp a verdict"));
        assert!(
            cert.certified,
            "{name}: tiers diverge: aloocv λ* = {:.3e} vs exact-LOO λ* = {:.3e} ({:.3} decades)",
            cert.aloo_lambda, cert.loo_lambda, cert.decades
        );
        assert!(cert.decades.is_finite() && cert.decades <= 1.0);
        assert_eq!(cert.aloo_lambda, rep.best_lambda);
        assert!(rep.best_error.is_finite());
        assert!(rep.skipped.is_empty(), "{name}: no cells may be lost");

        let plain = run_aloocv(&ds, &cfg(2)).unwrap();
        assert!(plain.certification.is_none(), "{name}: plain runs carry no verdict");
    }
}

/// The determinism contract, extended to the new tier: the whole report —
/// curve bits, selection, per-anchor RMSE — is identical at workers
/// {1, 2, 4} and for any row-batch size (per-column TRSM arithmetic is
/// independent of batch boundaries, and the merge is ascending).
#[test]
fn aloocv_is_bitwise_across_workers_and_batches() {
    let ds = well_conditioned(150, 16, 11);
    let serial = run_aloocv(&ds, &cfg(1)).unwrap();
    for workers in [2usize, 4] {
        let par = run_aloocv(&ds, &cfg(workers)).unwrap();
        assert_eq!(
            serial.curve, par.curve,
            "curve bits drifted at workers={workers}"
        );
        assert_eq!(serial.anchor_rmse, par.anchor_rmse);
        assert_eq!(serial.best_lambda, par.best_lambda);
        assert_eq!(serial.best_error, par.best_error);
        assert_eq!(serial.degradations.len(), par.degradations.len());
    }
    for batch in [1usize, 3, 64] {
        let batched = run_aloocv(
            &ds,
            &CvConfig {
                sweep_batch: batch,
                ..cfg(2)
            },
        )
        .unwrap();
        assert_eq!(
            serial.curve, batched.curve,
            "curve bits drifted at batch={batch}"
        );
        assert_eq!(serial.anchor_rmse, batched.anchor_rmse);
        assert_eq!(serial.best_lambda, batched.best_lambda);
    }
}

/// The leverage guard, on the shared breakdown fixture: row 0's lone `1e9`
/// spike makes its hat diagonal land exactly at 1.0 (`1e18/(1e18+λ)`
/// rounds to 1 for every λ ≤ 1), so the `1/(1−h)` closed form is void at
/// every anchor. The tier must escalate exactly that row to the exact-LOO
/// body — whose rank-1 downdate then hits the same pivot-0 breakdown and
/// climbs to the refactor rung — recorded as `cause = "leverage"`
/// degradations on surface `"aloocv"`, with every cell still served and
/// the whole report finite.
#[test]
fn leverage_rows_escalate_through_the_recovery_ladder() {
    let ds = spiked_dataset(40, 8, 5);
    let rep = run_aloocv(&ds, &cfg(2)).unwrap();
    let g = rep.anchor_lambdas.len();

    assert!(rep.skipped.is_empty(), "the ladder must rescue, not skip");
    assert_eq!(
        rep.degradations.len(),
        g,
        "exactly the spiked row escalates, once per anchor: {:?}",
        rep.degradations
    );
    for d in &rep.degradations {
        assert_eq!(d.surface, "aloocv");
        assert_eq!(d.fold, 0, "only row 0 may trip the leverage guard");
        assert_eq!(d.cause, "leverage");
        assert_eq!(d.rung, Rung::Refactor, "the refactor rung must rescue it");
        assert!(
            d.detail.contains("hat diagonal"),
            "the diagonal must be carried in the record: {}",
            d.detail
        );
    }

    // one ladder refactorization per anchor (row 0), nothing else O(d³)
    assert_eq!(rep.timer.count("factor"), g as u64);
    assert_eq!(rep.timer.count("chol"), g as u64, "ladder refactorizations");

    // every cell served: finite anchors, finite selection, no NaN anywhere
    assert!(rep.anchor_rmse.iter().all(|v| v.is_finite()));
    assert!(rep.curve.iter().all(|v| v.is_finite()));
    assert!(rep.best_lambda.is_finite() && rep.best_error.is_finite());
}
