//! The cross-mode conformance suite — the acceptance harness of the
//! factor-level k-fold engine.
//!
//! Every problem from `testutil::conformance` (well-conditioned,
//! ill-conditioned, rank-deficient) runs through the three execution modes
//! that must agree:
//!
//! - `fold_strategy = refactor` — the literal per-(fold, λ)
//!   `chol(H_f + λI)` pipeline, the oracle;
//! - `fold_strategy = downdate` — the factor-level downdate chains (the
//!   default hot path this suite exists to pin);
//! - `--mode loo` — the exact leave-one-out engine, as the cross-scheme
//!   sanity check on the selected λ.
//!
//! Asserted: λ* selection agrees (same grid cell between the two fold
//! strategies, same λ neighborhood for LOO), hold-out curves match to
//! ≤ 1e-9 RMS, the downdate path is bitwise identical at workers {1, 2, 4},
//! and an injected fold-granular downdate breakdown degrades to the
//! refactorize path for that fold only — recorded, never fatal.
//!
//! `ci.sh --conformance` runs exactly this file; the full CI gate includes
//! it via `cargo test`.

use picholesky::cv::loo::run_loo;
use picholesky::cv::solvers::SolverKind;
use picholesky::cv::{run_cv, CvConfig, FoldStrategy};
use picholesky::data::folds::kfold;
use picholesky::testutil::conformance::{
    assert_close_rms, spiked_dataset, suite, well_conditioned,
};

fn cfg(strategy: FoldStrategy, workers: usize) -> CvConfig {
    CvConfig {
        k_folds: 5,
        q_grid: 21,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: workers,
        fold_strategy: strategy,
        ..CvConfig::default()
    }
}

/// Snap a selected λ (possibly a geometric mean of grid values) to the
/// nearest grid cell, log-scale.
fn grid_cell(grid: &[f64], lam: f64) -> usize {
    grid.iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            let da = (a.ln() - lam.ln()).abs();
            let db = (b.ln() - lam.ln()).abs();
            da.partial_cmp(&db).unwrap()
        })
        .map(|(i, _)| i)
        .unwrap()
}

/// The headline conformance assertion: on every generator regime, the
/// factor-level downdate path reproduces the refactorize oracle — same λ*
/// cell (±1 across rounding-level ties), per-fold selections in step, mean
/// hold-out curves within 1e-9 RMS, and zero breakdown fallbacks.
#[test]
fn fold_strategies_agree_on_conformance_suite() {
    for (name, ds) in suite(150, 16, 11) {
        let refactor = run_cv(&ds, SolverKind::Chol, &cfg(FoldStrategy::Refactor, 1)).unwrap();
        let downdate = run_cv(&ds, SolverKind::Chol, &cfg(FoldStrategy::Downdate, 1)).unwrap();
        assert!(refactor.fallbacks.is_empty(), "{name}: oracle never falls back");
        assert!(
            downdate.fallbacks.is_empty(),
            "{name}: unexpected downdate breakdowns: {:?}",
            downdate.fallbacks
        );
        assert_close_rms(&refactor.mean_errors, &downdate.mean_errors, 1e-9);
        let (ri, di) = (
            grid_cell(&refactor.grid, refactor.best_lambda) as i64,
            grid_cell(&downdate.grid, downdate.best_lambda) as i64,
        );
        assert!(
            (ri - di).abs() <= 1,
            "{name}: λ* cells diverge: {ri} vs {di}"
        );
        for (f, ((la, ea), (lb, eb))) in refactor
            .fold_bests
            .iter()
            .zip(&downdate.fold_bests)
            .enumerate()
        {
            let (ca, cb) = (
                grid_cell(&refactor.grid, *la) as i64,
                grid_cell(&downdate.grid, *lb) as i64,
            );
            assert!((ca - cb).abs() <= 1, "{name}: fold {f} λ* cells {ca} vs {cb}");
            assert!((ea - eb).abs() < 1e-9, "{name}: fold {f} best error drifted");
        }
    }
}

/// The three-mode check on one dataset: refactor, downdate and exact LOO
/// all land their selected λ in the same neighborhood (LOO estimates the
/// same generalization optimum from n single-row splits, so it is held to
/// a one-decade agreement, not the rounding-level bar of the two k-fold
/// strategies).
#[test]
fn cross_mode_lambda_selection_agrees() {
    let ds = well_conditioned(150, 16, 11);
    let refactor = run_cv(&ds, SolverKind::Chol, &cfg(FoldStrategy::Refactor, 2)).unwrap();
    let downdate = run_cv(&ds, SolverKind::Chol, &cfg(FoldStrategy::Downdate, 2)).unwrap();
    let loo_cfg = CvConfig {
        g_samples: 6,
        ..cfg(FoldStrategy::Downdate, 2)
    };
    let loo = run_loo(&ds, &loo_cfg).unwrap();
    assert!(loo.skipped.is_empty(), "no LOO breakdowns expected");
    assert!(loo.best_lambda > 0.0 && loo.best_error.is_finite());

    let (ri, di) = (
        grid_cell(&refactor.grid, refactor.best_lambda) as i64,
        grid_cell(&downdate.grid, downdate.best_lambda) as i64,
    );
    assert!((ri - di).abs() <= 1, "k-fold strategies diverge: {ri} vs {di}");
    let dist = (loo.best_lambda.log10() - downdate.best_lambda.log10()).abs();
    assert!(
        dist < 1.0,
        "LOO λ* {:.3e} more than a decade from k-fold λ* {:.3e}",
        loo.best_lambda,
        downdate.best_lambda
    );
}

/// The downdate strategy is bitwise identical at workers {1, 2, 4}, for
/// both the exact sweep and piCholesky — the engine's determinism contract
/// extended to the new task kinds (anchor wave, fold-downdate wave,
/// anchored grid wave).
#[test]
fn downdate_strategy_bitwise_across_worker_counts() {
    let ds = well_conditioned(150, 16, 11);
    for solver in [SolverKind::Chol, SolverKind::PiChol] {
        let serial = run_cv(&ds, solver, &cfg(FoldStrategy::Downdate, 1)).unwrap();
        for workers in [2usize, 4] {
            let par = run_cv(&ds, solver, &cfg(FoldStrategy::Downdate, workers)).unwrap();
            assert_eq!(
                serial.mean_errors, par.mean_errors,
                "{solver:?}: curve bits drifted at workers={workers}"
            );
            assert_eq!(serial.best_lambda, par.best_lambda);
            assert_eq!(serial.best_error, par.best_error);
            assert_eq!(serial.fold_bests, par.fold_bests);
            assert_eq!(serial.fallbacks.len(), par.fallbacks.len());
        }
    }
}

/// Fold-granular breakdown injection, on the shared [`spiked_dataset`]
/// fixture: the fold whose validation block holds the spiked row 0 hits
/// pivot `1e18 − 1e18 = 0` at column 0 of its downdate — a deterministic
/// breakdown at every anchor, while every other fold downdates fine. The
/// engine must fall back to the refactorize path for that fold only,
/// record each cell in `CvReport::fallbacks`, and still produce the
/// pure-refactor curve.
#[test]
fn fold_breakdown_falls_back_and_is_recorded() {
    let ds = spiked_dataset(40, 8, 5);

    let (k, q) = (4usize, 9usize);
    let base = CvConfig {
        k_folds: k,
        q_grid: q,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: 2,
        ..CvConfig::default()
    };
    let down = run_cv(
        &ds,
        SolverKind::Chol,
        &CvConfig {
            fold_strategy: FoldStrategy::Downdate,
            ..base.clone()
        },
    )
    .unwrap();

    // the fold holding row 0 is determined by the same seeded split the
    // engine uses
    let spike_fold = kfold(ds.n(), k, base.seed)
        .iter()
        .position(|f| f.val.contains(&0))
        .unwrap();

    // recorded for that fold only, at every grid λ, with the failing column
    assert_eq!(down.fallbacks.len(), q, "one fallback per anchor λ");
    for fb in &down.fallbacks {
        assert_eq!(fb.fold, spike_fold, "only the spiked fold may fall back");
        assert_eq!(fb.error.pivot, 0, "failing column index must be carried");
        assert!(fb.error.value <= 0.0);
    }

    // structural accounting: every cell attempted the downdate, only the
    // spiked fold's cells refactorized
    assert_eq!(down.timer.count("factor"), q as u64);
    assert_eq!(down.timer.count("fold_downdate"), (q * k) as u64);
    assert_eq!(down.timer.count("chol"), q as u64, "fallback refactorizations");

    // and the final curve still matches the pure-refactor run: the fallback
    // fold bitwise (it ran the same code on the same H_f), the rest within
    // rounding
    let refr = run_cv(
        &ds,
        SolverKind::Chol,
        &CvConfig {
            fold_strategy: FoldStrategy::Refactor,
            ..base
        },
    )
    .unwrap();
    assert!(refr.fallbacks.is_empty());
    assert_eq!(
        down.fold_bests[spike_fold], refr.fold_bests[spike_fold],
        "the fallback fold must be bitwise the refactor path"
    );
    assert_close_rms(&down.mean_errors, &refr.mean_errors, 1e-9);
}
