//! The cross-mode conformance suite — the acceptance harness of the
//! factor-level k-fold engine.
//!
//! Every problem from `testutil::conformance` (well-conditioned,
//! ill-conditioned, rank-deficient) runs through the three execution modes
//! that must agree:
//!
//! - `fold_strategy = refactor` — the literal per-(fold, λ)
//!   `chol(H_f + λI)` pipeline, the oracle;
//! - `fold_strategy = downdate` — the factor-level downdate chains (the
//!   default hot path this suite exists to pin);
//! - `--mode loo` — the exact leave-one-out engine, as the cross-scheme
//!   sanity check on the selected λ.
//!
//! Asserted: λ* selection agrees (same grid cell between the two fold
//! strategies, same λ neighborhood for LOO), hold-out curves match to
//! ≤ 1e-9 RMS, the downdate path is bitwise identical at workers {1, 2, 4},
//! an injected fold-granular downdate breakdown escalates to the refactor
//! rung of the recovery ladder for that fold only — recorded as a
//! degradation, never fatal — and an exhausted drift budget forces
//! refactorizations that reproduce the pure-refactor oracle bitwise.
//!
//! `ci.sh --conformance` runs exactly this file; the full CI gate includes
//! it via `cargo test`.

use picholesky::cv::loo::run_loo;
use picholesky::cv::recovery::{RecoveryPolicy, Rung};
use picholesky::cv::solvers::SolverKind;
use picholesky::cv::{run_cv, CvConfig, FoldStrategy};
use picholesky::data::folds::kfold;
use picholesky::linalg::trust::TrustBudget;
use picholesky::testutil::conformance::{
    assert_close_rms, spiked_dataset, suite, well_conditioned,
};

fn cfg(strategy: FoldStrategy, workers: usize) -> CvConfig {
    CvConfig {
        k_folds: 5,
        q_grid: 21,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: workers,
        fold_strategy: strategy,
        ..CvConfig::default()
    }
}

/// Snap a selected λ (possibly a geometric mean of grid values) to the
/// nearest grid cell, log-scale.
fn grid_cell(grid: &[f64], lam: f64) -> usize {
    grid.iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            let da = (a.ln() - lam.ln()).abs();
            let db = (b.ln() - lam.ln()).abs();
            da.partial_cmp(&db).unwrap()
        })
        .map(|(i, _)| i)
        .unwrap()
}

/// The headline conformance assertion: on every generator regime, the
/// factor-level downdate path reproduces the refactorize oracle — same λ*
/// cell (±1 across rounding-level ties), per-fold selections in step, mean
/// hold-out curves within 1e-9 RMS, and zero recovery-ladder escalations.
#[test]
fn fold_strategies_agree_on_conformance_suite() {
    for (name, ds) in suite(150, 16, 11) {
        let refactor = run_cv(&ds, SolverKind::Chol, &cfg(FoldStrategy::Refactor, 1)).unwrap();
        let downdate = run_cv(&ds, SolverKind::Chol, &cfg(FoldStrategy::Downdate, 1)).unwrap();
        assert!(
            refactor.degradations.is_empty(),
            "{name}: oracle never degrades"
        );
        assert!(
            downdate.degradations.is_empty(),
            "{name}: unexpected downdate escalations: {:?}",
            downdate.degradations
        );
        assert_close_rms(&refactor.mean_errors, &downdate.mean_errors, 1e-9);
        let (ri, di) = (
            grid_cell(&refactor.grid, refactor.best_lambda) as i64,
            grid_cell(&downdate.grid, downdate.best_lambda) as i64,
        );
        assert!(
            (ri - di).abs() <= 1,
            "{name}: λ* cells diverge: {ri} vs {di}"
        );
        for (f, ((la, ea), (lb, eb))) in refactor
            .fold_bests
            .iter()
            .zip(&downdate.fold_bests)
            .enumerate()
        {
            let (ca, cb) = (
                grid_cell(&refactor.grid, *la) as i64,
                grid_cell(&downdate.grid, *lb) as i64,
            );
            assert!((ca - cb).abs() <= 1, "{name}: fold {f} λ* cells {ca} vs {cb}");
            assert!((ea - eb).abs() < 1e-9, "{name}: fold {f} best error drifted");
        }
    }
}

/// The three-mode check on one dataset: refactor, downdate and exact LOO
/// all land their selected λ in the same neighborhood (LOO estimates the
/// same generalization optimum from n single-row splits, so it is held to
/// a one-decade agreement, not the rounding-level bar of the two k-fold
/// strategies).
#[test]
fn cross_mode_lambda_selection_agrees() {
    let ds = well_conditioned(150, 16, 11);
    let refactor = run_cv(&ds, SolverKind::Chol, &cfg(FoldStrategy::Refactor, 2)).unwrap();
    let downdate = run_cv(&ds, SolverKind::Chol, &cfg(FoldStrategy::Downdate, 2)).unwrap();
    let loo_cfg = CvConfig {
        g_samples: 6,
        ..cfg(FoldStrategy::Downdate, 2)
    };
    let loo = run_loo(&ds, &loo_cfg).unwrap();
    assert!(loo.skipped.is_empty(), "no LOO breakdowns expected");
    assert!(loo.best_lambda > 0.0 && loo.best_error.is_finite());

    let (ri, di) = (
        grid_cell(&refactor.grid, refactor.best_lambda) as i64,
        grid_cell(&downdate.grid, downdate.best_lambda) as i64,
    );
    assert!((ri - di).abs() <= 1, "k-fold strategies diverge: {ri} vs {di}");
    let dist = (loo.best_lambda.log10() - downdate.best_lambda.log10()).abs();
    assert!(
        dist < 1.0,
        "LOO λ* {:.3e} more than a decade from k-fold λ* {:.3e}",
        loo.best_lambda,
        downdate.best_lambda
    );
}

/// The downdate strategy is bitwise identical at workers {1, 2, 4}, for
/// both the exact sweep and piCholesky — the engine's determinism contract
/// extended to the new task kinds (anchor wave, fold-downdate wave,
/// anchored grid wave).
#[test]
fn downdate_strategy_bitwise_across_worker_counts() {
    let ds = well_conditioned(150, 16, 11);
    for solver in [SolverKind::Chol, SolverKind::PiChol] {
        let serial = run_cv(&ds, solver, &cfg(FoldStrategy::Downdate, 1)).unwrap();
        for workers in [2usize, 4] {
            let par = run_cv(&ds, solver, &cfg(FoldStrategy::Downdate, workers)).unwrap();
            assert_eq!(
                serial.mean_errors, par.mean_errors,
                "{solver:?}: curve bits drifted at workers={workers}"
            );
            assert_eq!(serial.best_lambda, par.best_lambda);
            assert_eq!(serial.best_error, par.best_error);
            assert_eq!(serial.fold_bests, par.fold_bests);
            assert_eq!(serial.degradations.len(), par.degradations.len());
        }
    }
}

/// Fold-granular breakdown injection, on the shared [`spiked_dataset`]
/// fixture: the fold whose validation block holds the spiked row 0 hits
/// pivot `1e18 − 1e18 = 0` at column 0 of its downdate — a deterministic
/// breakdown at every anchor, while every other fold downdates fine. The
/// recovery ladder must escalate that fold's cells to the refactor rung
/// only, record each as a `cause = "breakdown"` degradation, and still
/// produce the pure-refactor curve.
#[test]
fn fold_breakdown_escalates_to_refactor_rung_and_is_recorded() {
    let ds = spiked_dataset(40, 8, 5);

    let (k, q) = (4usize, 9usize);
    let base = CvConfig {
        k_folds: k,
        q_grid: q,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: 2,
        ..CvConfig::default()
    };
    let down = run_cv(
        &ds,
        SolverKind::Chol,
        &CvConfig {
            fold_strategy: FoldStrategy::Downdate,
            ..base.clone()
        },
    )
    .unwrap();

    // the fold holding row 0 is determined by the same seeded split the
    // engine uses
    let spike_fold = kfold(ds.n(), k, base.seed)
        .iter()
        .position(|f| f.val.contains(&0))
        .unwrap();

    // recorded for that fold only, at every grid λ, stopped at rung 2 (the
    // plain refactorization rescues H_f + λI — no shift, no skip)
    assert_eq!(down.degradations.len(), q, "one degradation per grid λ");
    for d in &down.degradations {
        assert_eq!(d.surface, "kfold");
        assert_eq!(d.fold, spike_fold, "only the spiked fold may escalate");
        assert_eq!(d.cause, "breakdown");
        assert_eq!(d.rung, Rung::Refactor, "rung 2 must rescue the cell");
        assert!(
            d.detail.contains("pivot 0"),
            "failing column must be carried: {}",
            d.detail
        );
    }

    // structural accounting: every cell attempted the downdate, only the
    // spiked fold's cells refactorized
    assert_eq!(down.timer.count("factor"), q as u64);
    assert_eq!(down.timer.count("fold_downdate"), (q * k) as u64);
    assert_eq!(down.timer.count("chol"), q as u64, "ladder refactorizations");

    // and the final curve still matches the pure-refactor run: the rescued
    // fold bitwise (rung 2 ran the same code on the same H_f), the rest
    // within rounding
    let refr = run_cv(
        &ds,
        SolverKind::Chol,
        &CvConfig {
            fold_strategy: FoldStrategy::Refactor,
            ..base
        },
    )
    .unwrap();
    assert!(refr.degradations.is_empty());
    assert_eq!(
        down.fold_bests[spike_fold], refr.fold_bests[spike_fold],
        "the rescued fold must be bitwise the refactor path"
    );
    assert_close_rms(&down.mean_errors, &refr.mean_errors, 1e-9);
}

/// The drift budget demonstrably bites, end to end through the public API:
/// under a budget no finite drift can satisfy, every downdate-strategy cell
/// is forced through the refactor rung — recorded as `"drift-budget"`
/// degradations with positive trust — and the resulting curve is **bitwise**
/// the pure-refactor oracle (the forced rung runs the oracle's exact code
/// on the exact same `H_f + λI`), well inside the ≤ 1e-9 RMS acceptance
/// bound.
#[test]
fn exhausted_drift_budget_forces_refactorization_matching_oracle() {
    let ds = well_conditioned(120, 12, 17);
    let (k, q) = (4usize, 11usize);
    let base = CvConfig {
        k_folds: k,
        q_grid: q,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: 2,
        ..CvConfig::default()
    };
    let oracle = run_cv(
        &ds,
        SolverKind::Chol,
        &CvConfig {
            fold_strategy: FoldStrategy::Refactor,
            ..base.clone()
        },
    )
    .unwrap();
    let tight = run_cv(
        &ds,
        SolverKind::Chol,
        &CvConfig {
            fold_strategy: FoldStrategy::Downdate,
            recovery: RecoveryPolicy {
                budget: TrustBudget {
                    max_relative_drift: 1e-300,
                    max_hops: 0,
                },
                ..RecoveryPolicy::default()
            },
            ..base
        },
    )
    .unwrap();

    assert_eq!(
        tight.degradations.len(),
        k * q,
        "every (fold, λ) cell must hit the budget"
    );
    for d in &tight.degradations {
        assert_eq!(d.cause, "drift-budget");
        assert_eq!(d.rung, Rung::Refactor);
        assert!(d.trust > 0.0, "trust at failure must carry the drift bound");
    }
    // forced refactorizations are visible in the phase counts…
    assert_eq!(tight.timer.count("chol"), (k * q) as u64);
    // …and the curve is bitwise the oracle's
    assert_eq!(tight.mean_errors, oracle.mean_errors);
    assert_eq!(tight.fold_bests, oracle.fold_bests);
    assert_eq!(tight.best_lambda, oracle.best_lambda);
    assert_eq!(tight.best_error, oracle.best_error);
}
