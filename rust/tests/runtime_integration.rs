//! Integration tests: the AOT artifacts executed through PJRT, checked
//! against the native f64 linalg substrate.
//!
//! These need the `pjrt` feature (the real xla-backed runtime) AND
//! `make artifacts` to have run; without the feature the whole file is
//! compiled out (the default build ships a stub `runtime::Engine` that
//! cannot execute artifacts).
#![cfg(feature = "pjrt")]

use picholesky::coordinator::{HloFold, HloPipeline, Metrics};
use picholesky::data::synthetic::{DatasetKind, SyntheticDataset};
use picholesky::linalg::cholesky::cholesky_shifted;
use picholesky::linalg::gemm::{gemv_t, syrk_lower};
use picholesky::linalg::triangular::solve_cholesky;
use picholesky::runtime::{Engine, Tensor};
use picholesky::util::subsample_indices;
use picholesky::vectorize::{FullMatrix, VecStrategy};

fn engine() -> Engine {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    Engine::new(dir).expect("run `make artifacts` before `cargo test`")
}

/// A dataset shaped exactly like the h=64 AOT config.
fn fold64(engine: &Engine) -> (HloFold, usize) {
    let cfg = engine.config(64, None, None).unwrap();
    let total = cfg.n + cfg.n_val;
    let ds = SyntheticDataset::generate(DatasetKind::MnistLike, total, cfg.h, 0xFEED);
    (
        HloFold {
            xt: ds.x.slice(0, cfg.n, 0, cfg.h),
            yt: ds.y[..cfg.n].to_vec(),
            xv: ds.x.slice(cfg.n, total, 0, cfg.h),
            yv: ds.y[cfg.n..].to_vec(),
        },
        cfg.h,
    )
}

#[test]
fn manifest_lists_all_artifacts() {
    let engine = engine();
    for cfg in &engine.manifest().configs {
        for name in [
            "gram",
            "cholvec",
            "polyfit",
            "polyeval",
            "sweep",
            "chol_solve",
            "holdout",
            "exact_sweep",
        ] {
            assert!(cfg.files.contains_key(name), "{}: missing {name}", cfg.tag);
        }
    }
}

#[test]
fn gram_artifact_matches_native_syrk() {
    let engine = engine();
    let cfg = engine.config(64, None, None).unwrap();
    let (fold, _) = fold64(&engine);
    let metrics = Metrics::new();
    let pipe = HloPipeline::new(&engine, cfg, &metrics);

    let (h_t, g_t) = pipe.gram(&fold).unwrap();
    let h_native = syrk_lower(&fold.xt);
    let g_native = gemv_t(&fold.xt, &fold.yt);

    let h_hlo = h_t.to_matrix().unwrap();
    let scale = h_native.as_slice().iter().fold(0.0f64, |a, &b| a.max(b.abs()));
    assert!(
        h_hlo.max_abs_diff(&h_native) / scale < 1e-4,
        "gram mismatch: {:.3e} (scale {scale:.3e})",
        h_hlo.max_abs_diff(&h_native)
    );
    for (a, b) in g_t.to_vec_f64().iter().zip(&g_native) {
        assert!((a - b).abs() / scale < 1e-4);
    }
}

#[test]
fn cholvec_rows_match_native_factors() {
    let engine = engine();
    let cfg = engine.config(64, None, None).unwrap();
    let (fold, h) = fold64(&engine);
    let h_native = syrk_lower(&fold.xt);

    let lams = vec![0.01, 0.1, 0.5, 1.0];
    let out = engine
        .run(
            cfg,
            "cholvec",
            &[
                Tensor::from_matrix(&h_native),
                Tensor::from_vec(&lams),
            ],
        )
        .unwrap();
    let t = out[0].to_matrix().unwrap();
    assert_eq!(t.rows(), 4);
    assert_eq!(t.cols(), cfg.d_vec, "full-matrix layout: rows are h² long");
    for (s, &lam) in lams.iter().enumerate() {
        let l_native = cholesky_shifted(&h_native, lam).unwrap();
        let v_native = FullMatrix.vec(&l_native);
        let mut max_rel = 0.0f64;
        for (a, b) in t.row(s).iter().zip(&v_native) {
            let rel = (a - b).abs() / (b.abs().max(1.0));
            max_rel = max_rel.max(rel);
        }
        assert!(max_rel < 2e-3, "factor {s} (λ={lam}): max rel {max_rel:.2e}");
    }
    let _ = h;
}

#[test]
fn chol_solve_artifact_matches_native_solve() {
    let engine = engine();
    let cfg = engine.config(64, None, None).unwrap();
    let (fold, _) = fold64(&engine);
    let h_native = syrk_lower(&fold.xt);
    let g_native = gemv_t(&fold.xt, &fold.yt);
    let lam = 0.25;

    let out = engine
        .run(
            cfg,
            "chol_solve",
            &[
                Tensor::from_matrix(&h_native),
                Tensor::scalar(lam),
                Tensor::from_vec(&g_native),
            ],
        )
        .unwrap();
    let theta_hlo = out[0].to_vec_f64();

    let l = cholesky_shifted(&h_native, lam).unwrap();
    let theta_native = solve_cholesky(&l, &g_native);
    let scale = theta_native.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
    for (a, b) in theta_hlo.iter().zip(&theta_native) {
        assert!(
            (a - b).abs() / scale < 5e-3,
            "θ mismatch: {a} vs {b} (scale {scale:.2e})"
        );
    }
}

#[test]
fn full_fold_pipeline_agrees_with_native_cv() {
    let engine = engine();
    let cfg = engine.config(64, None, None).unwrap();
    let (fold, _) = fold64(&engine);
    let metrics = Metrics::new();
    let pipe = HloPipeline::new(&engine, cfg, &metrics);

    let hlo = pipe.run_fold(&fold, 1e-3, 1.0).unwrap();
    let exact = pipe.run_fold_exact(&fold, 1e-3, 1.0).unwrap();

    // the HLO piCholesky sweep must match the *native* piCholesky sweep
    // (same algorithm, f32 vs f64 substrate) — this isolates implementation
    // error from the method's own interpolation error
    let h_native = syrk_lower(&fold.xt);
    let g_native = gemv_t(&fold.xt, &fold.yt);
    let data = picholesky::cv::FoldData {
        xv: fold.xv.clone(),
        yv: fold.yv.clone(),
        h_mat: h_native,
        g_vec: g_native,
        train: Some(picholesky::cv::TrainSplit {
            xt: fold.xt.clone(),
            yt: fold.yt.clone(),
        }),
    };
    let cv_cfg = picholesky::cv::CvConfig::default();
    let mut timer = picholesky::util::PhaseTimer::new();
    let mut scratch = picholesky::linalg::Scratch::new();
    let native = picholesky::cv::solvers::sweep(
        picholesky::cv::solvers::SolverKind::PiChol,
        &data,
        &hlo.grid,
        &cv_cfg,
        &mut scratch,
        &mut timer,
    )
    .unwrap();
    for (i, (a, b)) in hlo.rmse.iter().zip(&native.errors).enumerate() {
        let rel = (a - b).abs() / b;
        assert!(rel < 0.02, "grid[{i}]: hlo pichol {a:.4} vs native pichol {b:.4}");
    }

    // method-level check: the interp sweep's argmin agrees with the exact
    // sweep's within a couple of grid steps (the paper's Table 4 criterion;
    // curve-level deviation away from the optimum is expected, see Fig. 7)
    assert!(
        (hlo.best_idx as i64 - exact.best_idx as i64).abs() <= 2,
        "selected λ differs: {} vs {}",
        hlo.best_lambda(),
        exact.best_lambda()
    );
    // and near the optimum the curves agree closely
    let span = 3.min(exact.best_idx);
    for i in (exact.best_idx - span)..=(exact.best_idx + span).min(hlo.rmse.len() - 1) {
        let rel = (hlo.rmse[i] - exact.rmse[i]).abs() / exact.rmse[i];
        assert!(rel < 0.02, "near-optimum grid[{i}]: {} vs {}", hlo.rmse[i], exact.rmse[i]);
    }

    // native (f64) exact sweep agrees with the HLO exact sweep
    let h_native = syrk_lower(&fold.xt);
    let g_native = gemv_t(&fold.xt, &fold.yt);
    for (i, &lam) in exact.grid.iter().enumerate().step_by(10) {
        let l = cholesky_shifted(&h_native, lam).unwrap();
        let theta = solve_cholesky(&l, &g_native);
        let err = picholesky::cv::holdout_error(
            &fold.xv,
            &fold.yv,
            &theta,
            picholesky::cv::Metric::Rmse,
        );
        let rel = (err - exact.rmse[i]).abs() / err;
        assert!(rel < 5e-3, "native vs hlo exact at λ={lam}: {err} vs {}", exact.rmse[i]);
    }
}

#[test]
fn polyeval_artifact_interpolates() {
    let engine = engine();
    let cfg = engine.config(64, None, None).unwrap();
    let (fold, _) = fold64(&engine);
    let h_native = syrk_lower(&fold.xt);
    let metrics = Metrics::new();
    let pipe = HloPipeline::new(&engine, cfg, &metrics);

    let grid = pipe.grid(1e-3, 1.0);
    let sample = pipe.sample_lambdas(&grid);
    assert_eq!(sample.len(), cfg.g);
    assert_eq!(subsample_indices(grid.len(), cfg.g).len(), cfg.g);

    let theta = pipe.fit(&Tensor::from_matrix(&h_native), &sample).unwrap();
    assert_eq!(theta.dims, vec![cfg.r + 1, cfg.d_pad]);

    let out = engine
        .run(cfg, "polyeval", &[theta, Tensor::from_vec(&grid)])
        .unwrap();
    let p = out[0].to_matrix().unwrap();
    assert_eq!((p.rows(), p.cols()), (cfg.m, cfg.d_vec));

    // HLO polyeval row ≈ native pichol interpolant at the same λ (same
    // algorithm + same full-matrix ordering, f32 vs f64)
    let mut timer = picholesky::util::PhaseTimer::new();
    let native = picholesky::pichol::fit(
        &h_native,
        &sample,
        &picholesky::pichol::FitOptions {
            degree: cfg.r,
            strategy: &FullMatrix,
        },
        &mut timer,
    )
    .unwrap();
    for &row_idx in &[0usize, cfg.m / 2, cfg.m - 1] {
        let v_native = native.eval_vec(grid[row_idx]);
        let mut max_rel = 0.0f64;
        for (a, b) in p.row(row_idx).iter().zip(&v_native) {
            max_rel = max_rel.max((a - b).abs() / b.abs().max(1.0));
        }
        assert!(
            max_rel < 2e-3,
            "interp row {row_idx}: hlo vs native max rel {max_rel:.2e}"
        );
    }
}

#[test]
fn shape_validation_rejects_wrong_inputs() {
    let engine = engine();
    let cfg = engine.config(64, None, None).unwrap();
    // wrong λ count into cholvec
    let err = engine
        .run(
            cfg,
            "cholvec",
            &[
                Tensor::new(vec![64, 64], vec![0.0; 64 * 64]),
                Tensor::from_vec(&[0.1; 3]), // g=4 expected
            ],
        )
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "got: {err}");
    // wrong input count
    let err = engine
        .run(cfg, "gram", &[Tensor::scalar(1.0)])
        .unwrap_err();
    assert!(err.to_string().contains("expected"), "got: {err}");
}
