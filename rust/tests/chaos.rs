//! The chaos suite — deterministic fault injection against the engine's
//! numerical-trust subsystem (`ci.sh --chaos`).
//!
//! Five fault classes, each seeded/addressed via `testutil::faults` so a
//! failure reproduces bit-for-bit:
//!
//! 1. non-finite rows/labels at ingest → rejected with a structured error
//!    naming the offender, before any Gram work;
//! 2. a Gram spike forcing a hyperbolic-downdate breakdown in a chosen
//!    fold → rescued at the refactor rung, localized, worker-invariant;
//! 3. drift-budget exhaustion mid-chain → forced refactorizations,
//!    recorded, bitwise the pure-refactor oracle;
//! 4. a worker panic at a chosen task index → bounded retry then
//!    quarantine, no panic escapes, untouched cells bitwise intact;
//! 5. a truncated/garbage `BENCH_kernels.json` → auto strategy degrades
//!    loudly to the default, while an absent file triggers the in-process
//!    calibration probe — both runs numerically interchangeable.
//!
//! Throughout: every run completes (`run_cv`/`run_loo` return, zero panics
//! escape the engine), each degradation is recorded exactly where injected,
//! and non-injected results are bitwise identical at workers {1, 2, 4}.
//!
//! The task-panic hook and the `PICHOL_BENCH_FILE` env var are
//! process-global, so EVERY test here serializes on [`global_lock`] — an
//! armed panic leaking into a concurrently-running engine sweep would
//! fabricate degradations the victim test never injected.

use picholesky::cv::loo::run_loo;
use picholesky::cv::recovery::{RecoveryPolicy, Rung};
use picholesky::cv::solvers::SolverKind;
use picholesky::cv::{run_cv, CvConfig, FoldStrategy};
use picholesky::data::folds::kfold;
use picholesky::linalg::trust::TrustBudget;
use picholesky::obs::Outcome;
use picholesky::testutil::conformance::{assert_close_rms, well_conditioned};
use picholesky::testutil::faults;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Snap a selected λ (possibly a geometric mean of grid values) to the
/// nearest grid cell, log-scale.
fn grid_cell(grid: &[f64], lam: f64) -> usize {
    grid.iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            let da = (a.ln() - lam.ln()).abs();
            let db = (b.ln() - lam.ln()).abs();
            da.partial_cmp(&db).unwrap()
        })
        .map(|(i, _)| i)
        .unwrap()
}

/// Serializes tests that touch process-global fault state. Poisoning is
/// ignored — a failed chaos test must not cascade into the others.
fn global_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn cfg(workers: usize) -> CvConfig {
    CvConfig {
        k_folds: 3,
        q_grid: 8,
        lambda_range: Some((1e-2, 1.0)),
        sweep_threads: workers,
        sweep_batch: 4,
        fold_strategy: FoldStrategy::Downdate,
        ..CvConfig::default()
    }
}

/// Fault class 1: non-finite data is stopped at the door with a structured
/// error naming the exact offender — k-fold and LOO entry points both.
#[test]
fn ingest_faults_are_rejected_with_structured_errors() {
    let _guard = global_lock();
    let mut ds = well_conditioned(60, 9, 3);
    faults::poison_row_nan(&mut ds, 17);
    let err = run_cv(&ds, SolverKind::Chol, &cfg(2))
        .expect_err("NaN row must be rejected at ingest");
    let msg = err.to_string();
    assert!(
        msg.contains("row 17") && msg.contains("non-finite"),
        "error must name the poisoned row: {msg}"
    );

    let mut ds = well_conditioned(60, 9, 3);
    faults::poison_label_inf(&mut ds, 5);
    let err = run_loo(&ds, &cfg(2)).expect_err("Inf label must be rejected at ingest");
    let msg = err.to_string();
    assert!(
        msg.contains("label") && msg.contains("row 5"),
        "error must name the poisoned label: {msg}"
    );
}

/// Fault class 2: a spiked Gram breaks the downdate of exactly one fold at
/// every grid λ; the ladder rescues each cell at the refactor rung. The
/// degradation record is localized to that fold, and the whole report —
/// curve bits and degradation records alike — is invariant across workers
/// {1, 2, 4} and across a seeded re-run.
#[test]
fn spiked_breakdown_is_localized_worker_invariant_and_reproducible() {
    let _guard = global_lock();
    let mut ds = well_conditioned(40, 8, 5);
    faults::spike_row(&mut ds, 0);
    let q = cfg(1).q_grid;
    let spike_fold = kfold(ds.n(), cfg(1).k_folds, cfg(1).seed)
        .iter()
        .position(|f| f.val.contains(&0))
        .unwrap();

    let serial = run_cv(&ds, SolverKind::Chol, &cfg(1)).unwrap();
    assert_eq!(serial.degradations.len(), q, "one rescue per grid λ");
    for d in &serial.degradations {
        assert_eq!((d.surface, d.fold), ("kfold", spike_fold));
        assert_eq!((d.cause, d.rung), ("breakdown", Rung::Refactor));
    }

    for workers in [2usize, 4] {
        let par = run_cv(&ds, SolverKind::Chol, &cfg(workers)).unwrap();
        assert_eq!(serial.mean_errors, par.mean_errors, "workers={workers}");
        assert_eq!(serial.fold_bests, par.fold_bests, "workers={workers}");
        let fmt = |r: &picholesky::cv::CvReport| {
            r.degradations.iter().map(|d| d.to_string()).collect::<Vec<_>>()
        };
        assert_eq!(fmt(&serial), fmt(&par), "degradation records must not depend on scheduling");
    }

    // seeded determinism: the same injected run, bit for bit
    let rerun = run_cv(&ds, SolverKind::Chol, &cfg(2)).unwrap();
    assert_eq!(serial.mean_errors, rerun.mean_errors);
    assert_eq!(serial.best_lambda, rerun.best_lambda);
}

/// Fault class 3: a budget no finite drift satisfies trips every downdate
/// cell mid-chain. Forced refactorizations are recorded per cell and the
/// result is bitwise the pure-refactor oracle at every worker count.
#[test]
fn drift_budget_exhaustion_forces_recorded_refactorizations() {
    let _guard = global_lock();
    let ds = well_conditioned(90, 10, 7);
    let oracle = run_cv(
        &ds,
        SolverKind::Chol,
        &CvConfig {
            fold_strategy: FoldStrategy::Refactor,
            ..cfg(1)
        },
    )
    .unwrap();
    let tight = |workers: usize| {
        run_cv(
            &ds,
            SolverKind::Chol,
            &CvConfig {
                recovery: RecoveryPolicy {
                    budget: TrustBudget {
                        max_relative_drift: 1e-300,
                        max_hops: 0,
                    },
                    ..RecoveryPolicy::default()
                },
                ..cfg(workers)
            },
        )
        .unwrap()
    };
    let base = tight(1);
    let cells = cfg(1).k_folds * cfg(1).q_grid;
    assert_eq!(base.degradations.len(), cells, "every cell hits the budget");
    for d in &base.degradations {
        assert_eq!((d.cause, d.rung), ("drift-budget", Rung::Refactor));
        assert!(d.trust > 0.0);
    }
    assert_eq!(base.mean_errors, oracle.mean_errors, "bitwise the oracle");
    assert_eq!(base.fold_bests, oracle.fold_bests);
    for workers in [2usize, 4] {
        let par = tight(workers);
        assert_eq!(base.mean_errors, par.mean_errors, "workers={workers}");
        assert_eq!(base.degradations.len(), par.degradations.len());
    }
}

/// Fault class 4: an armed panic in one grid task. With shots left after
/// the retry budget the task is quarantined — its span alone goes NaN, a
/// `"panic"` degradation names the task and span, no panic escapes, and
/// every untouched cell is bitwise the fault-free run. With a single shot,
/// the bounded retry re-runs the task and the report is bitwise fault-free
/// end to end.
#[test]
fn injected_task_panic_is_quarantined_exactly_where_armed() {
    let _guard = global_lock();
    let ds = well_conditioned(80, 9, 2);
    // spans: 2 tasks per fold (q=8, batch=4) × 3 folds; task 3 = fold 1,
    // cells 4..8
    let (armed_task, armed_fold, span) = (3usize, 1usize, 4usize..8);
    let baseline = run_cv(&ds, SolverKind::Chol, &cfg(2)).unwrap();
    assert!(baseline.degradations.is_empty());

    for workers in [1usize, 2] {
        let _armed = faults::PanicInjection::arm(armed_task, u64::MAX);
        let rep = run_cv(&ds, SolverKind::Chol, &cfg(workers))
            .expect("a quarantined task must not fail the run");
        assert_eq!(rep.degradations.len(), 1, "workers={workers}");
        let d = &rep.degradations[0];
        assert_eq!((d.surface, d.cause), ("task", "panic"));
        assert_eq!((d.fold, d.rung), (armed_fold, Rung::Skip));
        assert!(d.lambda.is_nan(), "a whole-task record carries no single λ");
        assert!(
            d.detail.contains("grid task 3 (cells 4..8)")
                && d.detail.contains("after 2 attempts")
                && d.detail.contains("injected fault"),
            "detail must name task, span, attempts and payload: {}",
            d.detail
        );
        // untouched cells bitwise; the lost span still aggregates finite
        // (NaN-aware mean over the two surviving folds)
        for g in 0..8 {
            if span.contains(&g) {
                assert!(
                    rep.mean_errors[g].is_finite(),
                    "lost cells still aggregate over the surviving folds"
                );
            } else {
                assert_eq!(
                    rep.mean_errors[g], baseline.mean_errors[g],
                    "cell {g} is outside the armed span and must be untouched"
                );
            }
        }
        for (fi, (fb, bb)) in rep.fold_bests.iter().zip(&baseline.fold_bests).enumerate() {
            if fi != armed_fold {
                assert_eq!(fb, bb, "fold {fi} must be bitwise fault-free");
            }
        }
    }

    // one shot only: the bounded retry absorbs the panic entirely
    let _armed = faults::PanicInjection::arm(armed_task, 1);
    let rep = run_cv(&ds, SolverKind::Chol, &cfg(2)).unwrap();
    assert!(
        rep.degradations.is_empty(),
        "a retried task serves its cells: {:?}",
        rep.degradations
    );
    assert_eq!(rep.mean_errors, baseline.mean_errors, "retry must be bitwise");
    assert_eq!(rep.fold_bests, baseline.fold_bests);
}

/// Fault class 5: a truncated/garbage bench-calibration file. The auto
/// strategy must degrade to the static default — recorded in
/// `strategy_source` — never a panic, never a half-parsed measurement. An
/// *absent* file is a different, louder path: the in-process probe measures
/// the crossover (`strategy_source = "probe"`), so a corrupt file can never
/// silently masquerade as "no measurement available". Whichever concrete
/// strategy either path lands on, both runs must agree on the curve to
/// conformance tolerance and select the same λ* grid cell — the strategies
/// are numerically interchangeable, which is what makes the fallbacks safe.
#[test]
fn garbage_bench_file_degrades_auto_to_default() {
    let _guard = global_lock();
    let ds = well_conditioned(60, 9, 4);
    let auto_cfg = CvConfig {
        fold_strategy: FoldStrategy::Auto,
        ..cfg(2)
    };

    let path = std::env::temp_dir().join("pichol_chaos_garbage_bench.json");
    faults::write_garbage_bench_file(&path).unwrap();
    std::env::set_var(picholesky::cv::strategy::BENCH_FILE_ENV, &path);
    let garbage = run_cv(&ds, SolverKind::Chol, &auto_cfg).unwrap();

    std::env::set_var(
        picholesky::cv::strategy::BENCH_FILE_ENV,
        std::env::temp_dir().join("pichol_chaos_no_such_file.json"),
    );
    let absent = run_cv(&ds, SolverKind::Chol, &auto_cfg).unwrap();
    std::env::remove_var(picholesky::cv::strategy::BENCH_FILE_ENV);
    let _ = std::fs::remove_file(&path);

    // garbage → loud static default; absent → measured in-process
    assert_eq!(garbage.strategy_source, "default");
    assert_eq!(garbage.fold_strategy, picholesky::cv::strategy::AUTO_DEFAULT);
    assert_eq!(absent.strategy_source, "probe");
    assert_ne!(absent.fold_strategy, FoldStrategy::Auto, "probe must resolve");

    // the probe's pick is timing-dependent, but both concrete strategies
    // compute the same sweep: curves agree to the conformance bar and the
    // selected λ* lands in the same grid cell
    assert_close_rms(&garbage.mean_errors, &absent.mean_errors, 1e-9);
    assert_eq!(
        grid_cell(&garbage.grid, garbage.best_lambda),
        grid_cell(&absent.grid, absent.best_lambda),
        "garbage-file and probe runs must select the same λ* cell"
    );
    assert!(garbage.degradations.is_empty());
    assert!(absent.degradations.is_empty());
}

/// Fault class 5b: a stale crossover probe. The probe measures packed
/// downdate timings through the *active* micro-kernel backend, so its
/// cache must be keyed by backend — flipping the backend after the first
/// probe (the `force_backend`/`PICHOL_KERNEL_BACKEND` scenario) must
/// trigger a fresh measurement, never reuse of the mismatched one; and
/// flipping *back* must return the original cached numbers, not probe a
/// third time. Driven through fake backend names so the test can't collide
/// with whatever real backends other tests have already probed.
#[test]
fn stale_strategy_probe_reprobes_on_backend_flip() {
    use picholesky::cv::strategy::{probe_for, probe_runs};
    let _guard = global_lock();

    let before = probe_runs();
    let a1 = probe_for("chaos-backend-a").expect("probe must measure on a healthy host");
    assert_eq!(probe_runs(), before + 1, "first backend: a real measurement");

    let a2 = probe_for("chaos-backend-a").unwrap();
    assert_eq!(probe_runs(), before + 1, "repeat hit must not re-measure");
    assert_eq!(a1, a2, "cache must hand back the identical measurement");

    let b1 = probe_for("chaos-backend-b").expect("flip must re-probe, not reuse");
    assert_eq!(
        probe_runs(),
        before + 2,
        "a backend flip is a cache miss: the stale measurement must not be reused"
    );
    assert!(b1.1 > 0.0 && b1.2 > 0.0);

    let a3 = probe_for("chaos-backend-a").unwrap();
    assert_eq!(probe_runs(), before + 2, "flip-back hits the per-backend map");
    assert_eq!(a1, a3, "the original backend's measurement survives the flip");
}

/// Observability under chaos: arming the event/histogram layer on a run
/// carrying an injected Gram breakdown AND a quarantined panicking task
/// changes no numeric output bitwise — the no-perturbation contract holds
/// exactly where it matters most — and both faults are visible in the
/// merged event log (degraded cells with their counts, the quarantine
/// with its recorded attempt total).
#[test]
fn enabling_obs_perturbs_nothing_under_injected_faults() {
    let _guard = global_lock();
    let mut ds = well_conditioned(40, 8, 5);
    faults::spike_row(&mut ds, 0);
    let armed_task = 1usize;

    let off = {
        let _armed = faults::PanicInjection::arm(armed_task, u64::MAX);
        run_cv(&ds, SolverKind::Chol, &cfg(2)).unwrap()
    };
    let on = {
        let _armed = faults::PanicInjection::arm(armed_task, u64::MAX);
        let on_cfg = CvConfig { obs: true, ..cfg(2) };
        run_cv(&ds, SolverKind::Chol, &on_cfg).unwrap()
    };

    assert!(off.obs.is_none());
    let obs = on.obs.as_ref().expect("armed run must carry a payload");
    assert_eq!(off.mean_errors, on.mean_errors, "obs must not perturb curve bits");
    assert_eq!(off.fold_bests, on.fold_bests);
    assert_eq!(off.best_lambda, on.best_lambda);
    assert_eq!(off.best_error, on.best_error);
    let fmt = |r: &picholesky::cv::CvReport| {
        r.degradations.iter().map(|d| d.to_string()).collect::<Vec<_>>()
    };
    assert_eq!(fmt(&off), fmt(&on), "same degradation records either way");

    // both faults surface in the event log: the quarantined task exactly
    // once (panicking attempts record nothing — the coordinator synthesizes
    // the terminal event from the recorded attempt total), and the spiked
    // fold's rescued cells as degraded grid events
    let quarantined: Vec<_> = obs
        .events
        .iter()
        .filter(|e| e.outcome == Outcome::Quarantined)
        .collect();
    assert_eq!(quarantined.len(), 1, "exactly the armed task is quarantined");
    assert_eq!(quarantined[0].kind, "grid");
    assert!(
        quarantined[0].attempt >= 2,
        "the quarantine event carries the exhausted retry budget, got {}",
        quarantined[0].attempt
    );
    assert!(
        obs.events.iter().any(|e| e.outcome == Outcome::Degraded && e.degradations > 0),
        "the spiked fold's ladder climbs must be visible in the log"
    );
}
