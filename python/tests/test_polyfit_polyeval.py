"""polyfit / polyeval kernels vs oracles + the paper's core smoothness claim."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import polyeval as pe
from compile.kernels import polyfit as pf
from compile.kernels import ref

from .conftest import assert_close, make_spd


@pytest.mark.parametrize("g,r,d", [(4, 2, 512), (4, 2, 1000), (6, 3, 2048), (5, 2, 77)])
def test_polyfit_matches_ref(rng, g, r, d):
    lams = jnp.asarray(np.sort(rng.uniform(0.01, 1.0, g)).astype(np.float32))
    t = jnp.asarray(rng.standard_normal((g, d)).astype(np.float32))
    theta = pf.polyfit(lams, t, r)
    theta_ref = ref.polyfit_ref(lams, t, r)
    # degree-3 Vandermonde normal equations are worse-conditioned in fp32;
    # the kernel's chol-solve and the ref's LU disagree at roundoff scale only
    tol = 1e-2 if r <= 2 else 5e-2
    assert_close(theta, theta_ref, rtol=tol, atol=tol / 2)


def test_polyfit_exact_recovery(rng):
    """If T really is polynomial in λ, the fit must recover it exactly."""
    g, r, d = 6, 2, 256
    lams = np.linspace(0.1, 1.0, g).astype(np.float32)
    coef = rng.standard_normal((r + 1, d)).astype(np.float32)
    v = np.stack([lams**p for p in range(r + 1)], axis=1)
    t = v @ coef
    theta = pf.polyfit(jnp.asarray(lams), jnp.asarray(t), r)
    assert_close(theta, coef, rtol=5e-2, atol=5e-3)


@pytest.mark.parametrize("m,d", [(31, 512), (7, 1000), (1, 128)])
def test_polyeval_matches_ref(rng, m, d):
    r = 2
    theta = jnp.asarray(rng.standard_normal((r + 1, d)).astype(np.float32))
    lams = jnp.asarray(rng.uniform(0.001, 1.0, m).astype(np.float32))
    p = pe.polyeval(theta, lams)
    p_ref = ref.polyeval_ref(theta, lams)
    assert_close(p, p_ref)


def test_fit_then_eval_roundtrip_at_samples(rng):
    """Interpolating degree r through g = r+1 points passes through the samples."""
    g, r, d = 3, 2, 400
    lams = jnp.asarray(np.array([0.1, 0.4, 0.9], np.float32))
    t = jnp.asarray(rng.standard_normal((g, d)).astype(np.float32))
    theta = pf.polyfit(lams, t, r)
    back = pe.polyeval(theta, lams)
    assert_close(back, t, rtol=5e-2, atol=5e-3)


def test_cholesky_entries_are_smooth_in_lambda(rng):
    """The paper's Figure 4 claim: quadratic fit of vec(chol(H+λI)) from g=6
    samples tracks the exact factors on a dense grid (NRMSE ≪ 1)."""
    h, g, r = 32, 6, 2
    hm = make_spd(rng, h, cond=1e4).astype(np.float64)
    lams_g = np.linspace(0.05, 1.0, g)
    lams_m = np.linspace(0.05, 1.0, 50)

    def vec_chol(lam):
        l = np.linalg.cholesky(hm + lam * np.eye(h))
        return l[np.tril_indices(h)]

    t = np.stack([vec_chol(s) for s in lams_g]).astype(np.float32)
    exact = np.stack([vec_chol(s) for s in lams_m])
    theta = pf.polyfit(jnp.asarray(lams_g.astype(np.float32)), jnp.asarray(t), r)
    interp = np.asarray(pe.polyeval(theta, jnp.asarray(lams_m.astype(np.float32))))
    nrmse = np.sqrt(np.mean((interp - exact) ** 2)) / (exact.std() + 1e-12)
    assert nrmse < 0.05, f"interpolated factors deviate: NRMSE={nrmse:.4f}"


@settings(max_examples=15, deadline=None)
@given(
    g=st.integers(min_value=4, max_value=8),
    d=st.integers(min_value=1, max_value=600),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_polyfit_hypothesis_shapes(g, d, seed):
    r = 2
    rr = np.random.default_rng(seed)
    lams = np.sort(rr.uniform(0.05, 1.0, g)).astype(np.float32)
    # keep sample points separated so V stays well-conditioned
    lams += np.arange(g, dtype=np.float32) * 0.05
    t = rr.standard_normal((g, d)).astype(np.float32)
    theta = pf.polyfit(jnp.asarray(lams), jnp.asarray(t), r)
    theta_ref = ref.polyfit_ref(jnp.asarray(lams), jnp.asarray(t), r)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(theta_ref), rtol=5e-2, atol=5e-2)


def test_projector_well_conditioned():
    """Paper §3.3: the monomial-basis V is well-conditioned on the λ ranges used."""
    lams = jnp.asarray(np.array([0.001, 0.01, 0.1, 1.0], np.float32))
    v = np.asarray(ref.vandermonde_ref(lams, 2), dtype=np.float64)
    assert np.linalg.cond(v) < 1e4
