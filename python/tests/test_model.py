"""L2 graph tests: shapes, numerics and the fused fold sweep end-to-end."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.shapes import TILE_D, pad_to, tri_d

from .conftest import assert_close, make_spd


@pytest.fixture
def small_problem(rng):
    """A tiny but honest ridge problem: n=256, h=32, with a meaningful λ*."""
    n, h = 256, 32
    x = rng.standard_normal((n, h)).astype(np.float32) / np.sqrt(h)
    w_true = rng.standard_normal(h).astype(np.float32)
    y = np.sign(x @ w_true + 0.5 * rng.standard_normal(n)).astype(np.float32)
    return x, y


def test_gram_fn(small_problem):
    x, y = small_problem
    hm, gv = model.gram_fn(jnp.asarray(x), jnp.asarray(y))
    assert hm.shape == (32, 32) and gv.shape == (32,)
    assert_close(hm, x.T @ x, rtol=1e-2, atol=1e-2)
    assert_close(gv, x.T @ y, rtol=1e-2, atol=1e-2)


def test_cholvec_fn_rows_are_exact_factors(rng):
    h, g = 24, 4
    hm = make_spd(rng, h)
    lams = np.array([0.01, 0.1, 0.5, 1.0], np.float32)
    t = model.cholvec_fn(jnp.asarray(hm), jnp.asarray(lams))
    # full-matrix vectorization: row s is the flattened h×h factor
    assert t.shape == (g, h * h)
    for s, lam in enumerate(lams):
        l = np.linalg.cholesky(hm.astype(np.float64) + lam * np.eye(h))
        assert_close(t[s], l.reshape(-1), rtol=1e-2, atol=1e-3)


def test_polyfit_fn_padded_output(rng):
    g, r, h = 4, 2, 32
    d = tri_d(h)
    lams = np.array([0.01, 0.1, 0.5, 1.0], np.float32)
    t = rng.standard_normal((g, d)).astype(np.float32)
    theta = model.polyfit_fn(jnp.asarray(lams), jnp.asarray(t), r)
    assert theta.shape == (r + 1, pad_to(d, TILE_D))
    theta_ref = ref.polyfit_ref(jnp.asarray(lams), jnp.asarray(t), r)
    assert_close(theta[:, :d], theta_ref, rtol=2e-2, atol=2e-3)
    # padding columns must be exactly zero (zero targets → zero coefficients)
    np.testing.assert_array_equal(np.asarray(theta[:, d:]), 0.0)


def test_chol_solve_fn_solves_system(rng):
    h = 32
    hm = make_spd(rng, h)
    gv = rng.standard_normal(h).astype(np.float32)
    lam = jnp.float32(0.3)
    th = np.asarray(model.chol_solve_fn(jnp.asarray(hm), lam, jnp.asarray(gv)))
    a = hm.astype(np.float64) + 0.3 * np.eye(h)
    expected = np.linalg.solve(a, gv.astype(np.float64))
    np.testing.assert_allclose(th, expected, rtol=1e-2, atol=1e-3)


def test_holdout_fn_metrics(rng):
    nv, h = 64, 16
    xv = rng.standard_normal((nv, h)).astype(np.float32)
    th = rng.standard_normal(h).astype(np.float32)
    yv = np.sign(xv @ th).astype(np.float32)
    out = np.asarray(model.holdout_fn(jnp.asarray(xv), jnp.asarray(yv), jnp.asarray(th)))
    assert out.shape == (2,)
    assert out[1] == 0.0  # θ perfectly separates its own labels
    pred = xv @ th
    assert_close(out[0], np.sqrt(np.mean((pred - yv) ** 2)), rtol=1e-3, atol=1e-4)


def test_sweep_fn_matches_exact_sweep_near_center(rng, small_problem):
    """The fused piCholesky sweep must track the exact sweep closely within the
    sampled λ interval — this is the paper's Figures 7/8 in miniature."""
    x, y = small_problem
    n, h = x.shape
    xv, yv = x[:64], y[:64]
    xt, yt = x[64:], y[64:]
    hm = (xt.T @ xt).astype(np.float32)
    gv = (xt.T @ yt).astype(np.float32)

    lams_g = np.array([0.02, 0.2, 0.6, 1.0], np.float32)
    lams_m = np.linspace(0.02, 1.0, 15).astype(np.float32)

    t = model.cholvec_fn(jnp.asarray(hm), jnp.asarray(lams_g))
    theta = model.polyfit_fn(jnp.asarray(lams_g), t, 2)
    errs_pi = np.asarray(
        model.sweep_fn(theta, jnp.asarray(lams_m), jnp.asarray(gv), jnp.asarray(xv), jnp.asarray(yv))
    )
    errs_exact = np.asarray(
        model.exact_sweep_fn(
            jnp.asarray(hm), jnp.asarray(lams_m), jnp.asarray(gv), jnp.asarray(xv), jnp.asarray(yv)
        )
    )
    assert errs_pi.shape == (15, 2) and errs_exact.shape == (15, 2)
    # RMSE curves agree to a few percent inside the interpolation interval
    np.testing.assert_allclose(errs_pi[:, 0], errs_exact[:, 0], rtol=0.05, atol=5e-3)
    # and crucially the argmin λ agrees (the paper's success criterion)
    assert abs(int(np.argmin(errs_pi[:, 0])) - int(np.argmin(errs_exact[:, 0]))) <= 1


def test_pichol_fit_wrapper(rng):
    h = 16
    hm = make_spd(rng, h)
    lams = np.array([0.05, 0.2, 0.6, 1.0], np.float32)
    theta = model.pichol_fit(jnp.asarray(hm), jnp.asarray(lams), 2)
    assert theta.shape[0] == 3
