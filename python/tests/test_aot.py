"""AOT pipeline tests: lowering produces parseable HLO + a faithful manifest."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.shapes import CONFIGS, PiCholConfig, pick_tile, pad_to, tri_d


def test_config_invariants():
    for cfg in CONFIGS:
        assert cfg.g > cfg.r, "Algorithm 1 requires g > r"
        assert cfg.d_pad >= cfg.d_tri and cfg.d_pad % 512 == 0
        assert cfg.n % 128 == 0 and cfg.h % 32 == 0


def test_tri_d():
    assert tri_d(1) == 1
    assert tri_d(64) == 64 * 65 // 2
    assert tri_d(16384) == 16384 * 16385 // 2  # the paper's biggest D


def test_pad_and_tile_helpers():
    assert pad_to(100, 512) == 512
    assert pad_to(512, 512) == 512
    assert pick_tile(256) == 128
    assert pick_tile(96) == 32
    assert pick_tile(50, prefer=64) in (2, 1)  # 50 = 2·25


def test_lowering_emits_hlo_text(tmp_path):
    cfg = PiCholConfig(h=32, n=128, n_val=64)
    lw = aot.lowerings(cfg)
    info = aot.lower_one("holdout", *lw["holdout"], str(tmp_path), cfg.tag())
    text = (tmp_path / info["file"]).read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # f32 params at the manifest shapes
    assert f"f32[{cfg.n_val},{cfg.h}]" in text


def test_lowering_all_names_small(tmp_path):
    """Every artifact name lowers without error at a tiny config."""
    cfg = PiCholConfig(h=32, n=128, n_val=64)
    for name, (fn, specs) in aot.lowerings(cfg).items():
        info = aot.lower_one(name, fn, specs, str(tmp_path), cfg.tag())
        assert info["bytes"] > 100, name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="run `make artifacts` first",
)
def test_manifest_matches_files():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    for cfg in manifest["configs"]:
        for name, info in cfg["files"].items():
            path = os.path.join(root, info["file"])
            assert os.path.exists(path), info["file"]
            assert os.path.getsize(path) == info["bytes"]
