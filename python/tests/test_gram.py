"""gram kernel vs pure-jnp oracle: exact-tile, ragged, dtype and tile sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram as gram_k
from compile.kernels import ref

from .conftest import assert_close


@pytest.mark.parametrize("n,h", [(128, 64), (256, 128), (512, 64), (96, 48)])
def test_gram_matches_ref(rng, n, h):
    x = rng.standard_normal((n, h)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    hm, gv = gram_k.gram(jnp.asarray(x), jnp.asarray(y))
    hr, gr = ref.gram_ref(jnp.asarray(x), jnp.asarray(y))
    assert_close(hm, hr, rtol=5e-3, atol=5e-3)
    assert_close(gv, gr, rtol=5e-3, atol=5e-3)


def test_gram_symmetry(rng):
    x = rng.standard_normal((256, 64)).astype(np.float32)
    hm, _ = gram_k.gram(jnp.asarray(x), jnp.asarray(x[:, 0]))
    assert_close(hm, jnp.asarray(np.asarray(hm)).T)


def test_gram_psd(rng):
    """XᵀX must be positive semi-definite."""
    x = rng.standard_normal((128, 32)).astype(np.float32)
    hm, _ = gram_k.gram(jnp.asarray(x), jnp.asarray(x[:, 0]))
    eigs = np.linalg.eigvalsh(np.asarray(hm, dtype=np.float64))
    assert eigs.min() > -1e-3


@pytest.mark.parametrize("tile", [32, 64, 128])
def test_gram_tile_invariance(rng, tile):
    """Result must not depend on the chosen block shape."""
    x = rng.standard_normal((256, 128)).astype(np.float32)
    y = rng.standard_normal(256).astype(np.float32)
    hm, gv = gram_k.gram(jnp.asarray(x), jnp.asarray(y), tile_h=tile, tile_k=tile)
    hr, gr = ref.gram_ref(jnp.asarray(x), jnp.asarray(y))
    assert_close(hm, hr, rtol=5e-3, atol=5e-3)
    assert_close(gv, gr, rtol=5e-3, atol=5e-3)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=3, max_value=200),
    h=st.integers(min_value=2, max_value=90),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_gram_hypothesis_ragged_shapes(n, h, seed):
    """Padding path: arbitrary (n, h), including shapes far from any tile."""
    r = np.random.default_rng(seed)
    x = r.standard_normal((n, h)).astype(np.float32)
    y = r.standard_normal(n).astype(np.float32)
    hm, gv = gram_k.gram(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(hm), x.T @ x, rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(np.asarray(gv), x.T @ y, rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("dtype", [np.float32])
def test_gram_dtype_preserved(rng, dtype):
    x = rng.standard_normal((64, 32)).astype(dtype)
    y = rng.standard_normal(64).astype(dtype)
    hm, gv = gram_k.gram(jnp.asarray(x), jnp.asarray(y))
    assert hm.dtype == dtype and gv.dtype == dtype
