"""Blocked trisolve kernel vs oracle: exact blocks, ragged h, multi-RHS."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import trisolve as ts
from compile.kernels import ref

from .conftest import assert_close, make_spd


def chol_of(rng, h, cond=1e3):
    a = make_spd(rng, h, cond=cond).astype(np.float64)
    return np.linalg.cholesky(a).astype(np.float32)


@pytest.mark.parametrize("h", [32, 64, 96, 128])
def test_trisolve_matches_ref(rng, h):
    l = chol_of(rng, h)
    g = rng.standard_normal(h).astype(np.float32)
    th = ts.trisolve(jnp.asarray(l), jnp.asarray(g))
    th_ref = ref.trisolve_ref(jnp.asarray(l), jnp.asarray(g))
    assert_close(th, th_ref, rtol=1e-2, atol=1e-3)


@pytest.mark.parametrize("h", [17, 33, 50, 63])
def test_trisolve_ragged_padding(rng, h):
    """h not a block multiple exercises the identity-diagonal padding path."""
    l = chol_of(rng, h)
    g = rng.standard_normal(h).astype(np.float32)
    th = ts.trisolve(jnp.asarray(l), jnp.asarray(g))
    th_ref = ref.trisolve_ref(jnp.asarray(l), jnp.asarray(g))
    assert_close(th, th_ref, rtol=1e-2, atol=1e-3)


def test_trisolve_multi_rhs(rng):
    h, k = 64, 5
    l = chol_of(rng, h)
    g = rng.standard_normal((h, k)).astype(np.float32)
    th = ts.trisolve(jnp.asarray(l), jnp.asarray(g))
    th_ref = ref.trisolve_ref(jnp.asarray(l), jnp.asarray(g))
    assert th.shape == (h, k)
    assert_close(th, th_ref, rtol=1e-2, atol=1e-3)


def test_trisolve_residual(rng):
    """‖LLᵀθ − g‖ / ‖g‖ must be at fp32 roundoff scale."""
    h = 128
    l = chol_of(rng, h)
    g = rng.standard_normal(h).astype(np.float32)
    th = np.asarray(ts.trisolve(jnp.asarray(l), jnp.asarray(g)), dtype=np.float64)
    l64 = l.astype(np.float64)
    res = np.linalg.norm(l64 @ (l64.T @ th) - g) / np.linalg.norm(g)
    assert res < 1e-4


@pytest.mark.parametrize("bs", [8, 16, 32, 64])
def test_trisolve_block_size_invariance(rng, bs):
    h = 64
    l = chol_of(rng, h)
    g = rng.standard_normal(h).astype(np.float32)
    th = ts.trisolve(jnp.asarray(l), jnp.asarray(g), bs=bs)
    th_ref = ref.trisolve_ref(jnp.asarray(l), jnp.asarray(g))
    assert_close(th, th_ref, rtol=1e-2, atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(h=st.integers(min_value=2, max_value=80), seed=st.integers(min_value=0, max_value=2**31))
def test_trisolve_hypothesis(h, seed):
    r = np.random.default_rng(seed)
    a = r.standard_normal((h, h))
    l = (np.tril(a, -1) + np.diag(np.abs(a.diagonal()) + h)).astype(np.float32)
    g = r.standard_normal(h).astype(np.float32)
    th = np.asarray(ts.trisolve(jnp.asarray(l), jnp.asarray(g)), dtype=np.float64)
    l64 = l.astype(np.float64)
    res = np.linalg.norm(l64 @ (l64.T @ th) - g) / (np.linalg.norm(g) + 1e-30)
    assert res < 1e-3
