"""Shared fixtures for the kernel/model test-suite."""

import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)


def make_spd(rng, h, dtype=np.float32, cond=1e3):
    """Random SPD matrix with controlled condition number — the Hessian stand-in."""
    q, _ = np.linalg.qr(rng.standard_normal((h, h)))
    eigs = np.logspace(0, np.log10(cond), h)
    return ((q * eigs) @ q.T).astype(dtype)


@pytest.fixture
def spd64(rng):
    return make_spd(rng, 64).astype(np.float32)


def assert_close(a, b, rtol=2e-3, atol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)
