"""blockops primitives and the Pallas blocked-Cholesky kernel vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import blockops, cholesky as chol_k

from .conftest import assert_close, make_spd


def rand_lower(rng, s, scale=0.3):
    a = rng.standard_normal((s, s))
    return (np.tril(a, -1) * scale + np.diag(np.abs(a.diagonal()) + s)).astype(np.float32)


@pytest.mark.parametrize("s", [1, 2, 5, 16, 32])
def test_chol_unblocked(rng, s):
    a = make_spd(rng, s, cond=1e3).astype(np.float64)
    l = np.asarray(blockops.chol_unblocked(jnp.asarray(a, jnp.float32)))
    expect = np.linalg.cholesky(a)
    np.testing.assert_allclose(l, expect, rtol=5e-3, atol=5e-3)
    # strictly lower-triangular output
    assert np.allclose(np.triu(l, 1), 0.0)


@pytest.mark.parametrize("s,k", [(8, 1), (16, 4), (32, 3)])
def test_trsolve_lower_and_upper_t(rng, s, k):
    l = rand_lower(rng, s)
    b = rng.standard_normal((s, k)).astype(np.float32)
    x = np.asarray(blockops.trsolve_lower(jnp.asarray(l), jnp.asarray(b)))
    np.testing.assert_allclose(l @ x, b, rtol=1e-3, atol=1e-4)
    y = np.asarray(blockops.trsolve_upper_t(jnp.asarray(l), jnp.asarray(b)))
    np.testing.assert_allclose(l.T @ y, b, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("m,s", [(4, 8), (16, 16), (40, 32)])
def test_trsolve_right_lt(rng, m, s):
    l = rand_lower(rng, s)
    c = rng.standard_normal((m, s)).astype(np.float32)
    x = np.asarray(blockops.trsolve_right_lt(jnp.asarray(c), jnp.asarray(l)))
    np.testing.assert_allclose(x @ l.T, c, rtol=1e-3, atol=1e-4)


def test_spd_solve(rng):
    a = make_spd(rng, 4, cond=100.0)
    b = rng.standard_normal((4, 6)).astype(np.float32)
    x = np.asarray(blockops.spd_solve(jnp.asarray(a), jnp.asarray(b)))
    expect = np.linalg.solve(a.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(x, expect, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("h", [32, 64, 96, 128])
def test_pallas_cholesky_matches_numpy(rng, h):
    a = make_spd(rng, h, cond=1e4).astype(np.float64)
    l = np.asarray(chol_k.cholesky(jnp.asarray(a, jnp.float32)))
    expect = np.linalg.cholesky(a)
    np.testing.assert_allclose(l, expect, rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("h", [17, 33, 50])
def test_pallas_cholesky_ragged(rng, h):
    """Identity-padding path for h not a multiple of the panel width."""
    a = make_spd(rng, h, cond=1e3).astype(np.float64)
    l = np.asarray(chol_k.cholesky(jnp.asarray(a, jnp.float32)))
    expect = np.linalg.cholesky(a)
    np.testing.assert_allclose(l, expect, rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("bs", [8, 16, 32, 64])
def test_pallas_cholesky_block_invariance(rng, bs):
    a = make_spd(rng, 64, cond=1e3).astype(np.float64)
    l = np.asarray(chol_k.cholesky(jnp.asarray(a, jnp.float32), bs=bs))
    expect = np.linalg.cholesky(a)
    np.testing.assert_allclose(l, expect, rtol=2e-2, atol=2e-3)


def test_pallas_cholesky_reconstruction(rng):
    a = make_spd(rng, 96, cond=1e4)
    l = np.asarray(chol_k.cholesky(jnp.asarray(a)), dtype=np.float64)
    rec = l @ l.T
    np.testing.assert_allclose(rec, a.astype(np.float64), rtol=1e-3, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(h=st.integers(min_value=2, max_value=70), seed=st.integers(min_value=0, max_value=2**31))
def test_pallas_cholesky_hypothesis(h, seed):
    r = np.random.default_rng(seed)
    q, _ = np.linalg.qr(r.standard_normal((h, h)))
    eigs = np.logspace(0, 2, h)
    a = (q * eigs) @ q.T
    l = np.asarray(chol_k.cholesky(jnp.asarray(a, jnp.float32)), dtype=np.float64)
    rec = l @ l.T
    rel = np.abs(rec - a).max() / np.abs(a).max()
    assert rel < 1e-3, f"h={h}: reconstruction rel err {rel}"
