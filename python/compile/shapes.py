"""Canonical shape manifest shared by the AOT lowering and the rust runtime.

Every artifact is lowered at a fixed shape (PJRT AOT requires static shapes).
The rust runtime reads `artifacts/manifest.json` and refuses to feed an
executable a tensor whose shape differs from what it was lowered at, so this
file is the single source of truth for the interchange contract.
"""

from dataclasses import dataclass, field, asdict

#: Column tile for streaming the huge D axis (D = h(h+1)/2) through VMEM.
TILE_D = 512

#: Block size for the blocked triangular substitution kernel.
TRISOLVE_BS = 32


def tri_d(h: int) -> int:
    """Number of entries in the lower triangle of an h x h factor."""
    return h * (h + 1) // 2


def pad_to(n: int, mult: int) -> int:
    """Round n up to a multiple of mult."""
    return ((n + mult - 1) // mult) * mult


def pick_tile(dim: int, prefer: int = 128) -> int:
    """Largest power-of-two tile <= prefer that divides dim (>=8 when possible)."""
    t = prefer
    while t > 8:
        if dim % t == 0:
            return t
        t //= 2
    return t if dim % t == 0 else 1


@dataclass(frozen=True)
class PiCholConfig:
    """One AOT configuration: every artifact name below is lowered per config.

    h       factor dimension (d+1 in the paper; h x h Hessian)
    n       training rows fed to the gram artifact
    n_val   validation rows fed to the sweep/holdout artifacts
    g       number of exact Cholesky sample points (paper: 4)
    r       polynomial degree (paper: 2)
    m       dense lambda-grid size swept per fold (paper: 31)
    """

    h: int
    n: int
    n_val: int
    g: int = 4
    r: int = 2
    m: int = 31

    @property
    def d_tri(self) -> int:
        return tri_d(self.h)

    @property
    def d_vec(self) -> int:
        """Vector length of the HLO path's factor flattening.

        The HLO pipeline uses the paper's **full-matrix** strategy (§5): a
        plain h² reshape instead of a triangle gather. Profiling on the CPU
        PJRT backend (EXPERIMENTS.md §Perf) showed XLA's gather/scatter for
        the D = h(h+1)/2 triangle costing ~10× the factorization itself, so
        the Table 1 trade-off (aligned copies, 2× fit/interp flops) is the
        right choice here. The rust-native path keeps the recursive strategy.
        """
        return self.h * self.h

    @property
    def d_pad(self) -> int:
        return pad_to(self.d_vec, TILE_D)

    def tag(self) -> str:
        return f"h{self.h}_g{self.g}_r{self.r}_m{self.m}"

    def manifest_entry(self) -> dict:
        e = asdict(self)
        e["d_tri"] = self.d_tri
        e["d_vec"] = self.d_vec
        e["d_pad"] = self.d_pad
        e["vec_strategy"] = "full-matrix"
        e["tag"] = self.tag()
        return e


#: Configurations lowered by `make artifacts`. h=64 keeps tests fast; h=256 is
#: the end-to-end example's working size; h=512 exists for the perf pass.
CONFIGS = [
    PiCholConfig(h=64, n=512, n_val=128),
    PiCholConfig(h=128, n=1024, n_val=256),
    PiCholConfig(h=256, n=2048, n_val=512),
    PiCholConfig(h=256, n=2048, n_val=512, g=6, r=3),
    PiCholConfig(h=512, n=4096, n_val=1024),
]

#: Artifact basenames; each is lowered once per config as `<name>_<tag>.hlo.txt`.
ARTIFACTS = [
    "gram",        # (X[n,h], y[n])             -> (H[h,h], g[h])
    "cholvec",     # (H, lams[g])               -> T[g, D]    exact factors, row-vec
    "polyfit",     # (lams[g], T[g, D])         -> Theta[r+1, D_pad]
    "polyeval",    # (Theta, lams_m[m])         -> P[m, D_pad]
    "sweep",       # (Theta, lams_m, g[h], Xv, yv) -> errs[m, 2]  (rmse, miscls)
    "chol_solve",  # (H, lam, g[h])             -> theta[h]   exact per-lambda solve
    "holdout",     # (Xv, yv, theta[h])         -> (rmse, miscls)
]
