"""Build-time compile path for piCholesky.

Everything under this package runs ONCE, at `make artifacts` time: the Pallas
kernels (L1) and the JAX graphs that compose them (L2) are lowered to HLO text
that the rust coordinator (L3) loads through PJRT. Nothing here is imported on
the request path.
"""
