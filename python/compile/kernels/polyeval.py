"""Pallas kernel for the interpolation payoff step: ``P = B·Θ``.

Given the fitted coefficients Θ ((r+1)×D) and a *batch* of m dense-grid λ
values, each row of ``P = B·Θ`` (B[t] = [1, λ_t, …, λ_t^r]) is an interpolated
vec(L^t) at cost O(r·D) = O(r·d²) — the paper's headline speedup over the
O(d³) exact factorization (§3.3, "Computational Complexity").

Batching all m λ's into one kernel launch is the L2-level fusion the paper
gets from BLAS-3: one pass over Θ's D axis serves the whole grid, so HBM
traffic is ``(r+1+m)·D`` instead of ``m·(r+1)·D``. Grid and VMEM budget
mirror :mod:`polyfit`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import TILE_D
from .ref import vandermonde_ref


def _eval_kernel(b_ref, th_ref, o_ref):
    """One D-tile: ``P_tile = B · Θ_tile`` (B fully VMEM-resident)."""
    o_ref[...] = jax.lax.dot_general(
        b_ref[...],
        th_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("tile_d",))
def eval_tiled(b: jax.Array, theta: jax.Array, tile_d: int = TILE_D) -> jax.Array:
    """``P = B·Θ`` for D divisible by tile_d."""
    m, rp1 = b.shape
    _, d = theta.shape
    return pl.pallas_call(
        _eval_kernel,
        grid=(d // tile_d,),
        in_specs=[
            pl.BlockSpec((m, rp1), lambda i: (0, 0)),
            pl.BlockSpec((rp1, tile_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, d), theta.dtype),
        interpret=True,
    )(b.astype(theta.dtype), theta)


def polyeval(theta: jax.Array, lams: jax.Array, tile_d: int = TILE_D) -> jax.Array:
    """Public API: interpolated vec(L) rows for a batch of λ's, arbitrary D."""
    rp1, d = theta.shape
    b = vandermonde_ref(lams, rp1 - 1)
    pad = (-d) % tile_d
    tp = jnp.pad(theta, ((0, 0), (0, pad))) if pad else theta
    p = eval_tiled(b, tp, tile_d=tile_d)
    return p[:, :d]
