"""Blocked triangular-solve Pallas kernel: ``LLᵀθ = g`` (paper §3.2).

Once a factor L is in hand (exact or interpolated), each candidate λ costs one
forward and one backward substitution — O(d²), the per-λ request-path cost the
coordinator pays m times per fold. The kernel keeps the whole factor VMEM-
resident (h ≤ 1024 ⇒ ≤ 4 MB fp32) and walks it in ``bs×bs`` blocks:

- diagonal blocks: dense triangular solve of one bs-block (the VPU part),
- off-diagonal updates: ``bs×bs @ bs×nrhs`` MXU mat-vecs accumulated through a
  ``fori_loop`` carry.

A single-program grid: the substitution recurrence is inherently sequential
across block rows. Parallelism comes from the L3 coordinator running many λ's
and folds concurrently — the same shape as the paper's multithreaded BLAS-2.

The intermediate ``w`` (solution of the forward pass) is materialized as a
second kernel output rather than scratch so the kernel stays portable across
pallas backends; XLA dead-code-eliminates it from the AOT artifact when the
caller drops it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blockops
from ..shapes import TRISOLVE_BS


def _make_kernel(h: int, bs: int):
    """Build the blocked substitution kernel for a fixed (h, bs)."""
    nb = h // bs

    def kernel(l_ref, g_ref, o_ref, w_ref):
        # ---- forward pass: L w = g ----
        def fwd_body(i, _):
            gi = g_ref[pl.dslice(i * bs, bs), :]

            def inner(j, acc):
                lij = l_ref[pl.dslice(i * bs, bs), pl.dslice(j * bs, bs)]
                wj = w_ref[pl.dslice(j * bs, bs), :]
                return acc + lij @ wj

            acc = jax.lax.fori_loop(0, i, inner, jnp.zeros_like(gi))
            lii = l_ref[pl.dslice(i * bs, bs), pl.dslice(i * bs, bs)]
            # custom-call-free substitution (see blockops): LAPACK FFI calls
            # would not run on the rust PJRT client
            w_ref[pl.dslice(i * bs, bs), :] = blockops.trsolve_lower(lii, gi - acc)
            return 0

        jax.lax.fori_loop(0, nb, fwd_body, 0)

        # ---- backward pass: Lᵀ θ = w ----
        def bwd_body(ir, _):
            i = nb - 1 - ir
            wi = w_ref[pl.dslice(i * bs, bs), :]

            def inner(joff, acc):
                jj = i + 1 + joff
                lji = l_ref[pl.dslice(jj * bs, bs), pl.dslice(i * bs, bs)]
                tj = o_ref[pl.dslice(jj * bs, bs), :]
                return acc + lji.T @ tj

            acc = jax.lax.fori_loop(0, nb - 1 - i, inner, jnp.zeros_like(wi))
            lii = l_ref[pl.dslice(i * bs, bs), pl.dslice(i * bs, bs)]
            o_ref[pl.dslice(i * bs, bs), :] = blockops.trsolve_upper_t(lii, wi - acc)
            return 0

        jax.lax.fori_loop(0, nb, bwd_body, 0)

    return kernel


@functools.partial(jax.jit, static_argnames=("bs",))
def trisolve_blocked(l: jax.Array, g2: jax.Array, bs: int = TRISOLVE_BS) -> jax.Array:
    """Solve ``LLᵀθ = g2`` for h divisible by bs; g2 is (h, nrhs)."""
    h = l.shape[0]
    theta, _w = pl.pallas_call(
        _make_kernel(h, bs),
        out_shape=(
            jax.ShapeDtypeStruct(g2.shape, g2.dtype),
            jax.ShapeDtypeStruct(g2.shape, g2.dtype),
        ),
        interpret=True,
    )(l, g2)
    return theta


def trisolve(l: jax.Array, g: jax.Array, bs: int = TRISOLVE_BS) -> jax.Array:
    """Public API: solve ``LLᵀθ = g`` for arbitrary h.

    Pads to a bs multiple with an identity diagonal block; the padded rows of
    g are zero so the padding never feeds back into the true solution.
    """
    h = l.shape[0]
    pad = (-h) % bs
    squeeze = g.ndim == 1
    g2 = g.reshape(h, -1)
    if pad:
        eye_tail = jnp.diag(jnp.pad(jnp.zeros(h, l.dtype), (0, pad), constant_values=1.0))
        l = jnp.pad(l, ((0, pad), (0, pad))) + eye_tail
        g2 = jnp.pad(g2, ((0, pad), (0, 0)))
    out = trisolve_blocked(l, g2, bs=bs)[:h]
    return out.reshape(h) if squeeze else out
