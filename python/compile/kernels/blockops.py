"""Custom-call-free dense building blocks.

jax's CPU lowering turns ``jnp.linalg.cholesky`` / ``solve_triangular`` /
``jnp.linalg.solve`` into LAPACK FFI custom-calls (``lapack_spotrf_ffi`` …)
that the rust side's xla_extension 0.5.1 cannot execute. Everything here is
written with plain jnp ops + masked ``fori_loop`` recurrences so the whole
pipeline lowers to portable HLO:

- :func:`chol_unblocked`    — column-Cholesky of one (small) block
- :func:`trsolve_lower`     — solve ``L X = B``   (forward substitution)
- :func:`trsolve_upper_t`   — solve ``Lᵀ X = B``  (backward substitution)
- :func:`trsolve_right_lt`  — solve ``X Lᵀ = C``  (the TRSM panel step)
- :func:`spd_solve`         — solve SPD ``A X = B`` via the above

Shapes stay static throughout: loop indices select rows/columns with
``arange``-masks instead of dynamic slices, so each iteration is O(s²) dense
work — ideal fodder for the VPU, and exactly the paper's BLAS-2 panel
economics.
"""

import jax
import jax.numpy as jnp


def chol_unblocked(b: jax.Array) -> jax.Array:
    """Cholesky factor of a (small) SPD block via masked column recurrence.

    Column j of L depends on columns < j only; the fori_loop carries the
    partially-built factor and masks future columns out of the inner products.
    """
    s = b.shape[0]
    idx = jnp.arange(s)

    def body(j, l):
        # inner products with already-built columns (< j)
        colmask = (idx < j).astype(b.dtype)  # (s,)
        lj = l[j, :] * colmask  # row j restricted to built columns
        # c[i] = B[i,j] − Σ_{k<j} L[i,k]·L[j,k]
        c = b[:, j] - (l * colmask[None, :]) @ lj
        cj = c[j]
        ljj = jnp.sqrt(jnp.maximum(cj, 1e-30))
        newcol = jnp.where(idx > j, c / ljj, 0.0)
        newcol = newcol.at[j].set(ljj)
        keep = (idx != j).astype(b.dtype)
        return l * keep[None, :] + newcol[:, None] * (idx == j).astype(b.dtype)[None, :]

    l0 = jnp.zeros_like(b)
    return jax.lax.fori_loop(0, s, body, l0)


def trsolve_lower(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve ``L X = B`` for lower-triangular L (B is (s, k))."""
    s = l.shape[0]
    idx = jnp.arange(s)

    def body(i, x):
        # x_i = (b_i − L[i,:i]·X[:i]) / L[i,i]
        rowmask = (idx < i).astype(b.dtype)
        li = l[i, :] * rowmask
        xi = (b[i, :] - li @ x) / l[i, i]
        sel = (idx == i).astype(b.dtype)[:, None]
        return x * (1.0 - sel) + xi[None, :] * sel

    return jax.lax.fori_loop(0, s, body, jnp.zeros_like(b))


def trsolve_upper_t(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve ``Lᵀ X = B`` for lower-triangular L (B is (s, k))."""
    s = l.shape[0]
    idx = jnp.arange(s)

    def body(step, x):
        i = s - 1 - step
        rowmask = (idx > i).astype(b.dtype)
        # Lᵀ[i, :] = L[:, i]; entries with index > i are below the diagonal
        col = l[:, i] * rowmask
        xi = (b[i, :] - col @ x) / l[i, i]
        sel = (idx == i).astype(b.dtype)[:, None]
        return x * (1.0 - sel) + xi[None, :] * sel

    return jax.lax.fori_loop(0, s, body, jnp.zeros_like(b))


def trsolve_right_lt(c: jax.Array, l: jax.Array) -> jax.Array:
    """Solve ``X Lᵀ = C`` for lower-triangular L (C is (m, s)) — the blocked
    Cholesky TRSM panel step: X[:, j] = (C[:, j] − X[:, :j]·L[j, :j]ᵀ)/L[j,j]."""
    s = l.shape[0]
    idx = jnp.arange(s)

    def body(j, x):
        colmask = (idx < j).astype(c.dtype)
        lj = l[j, :] * colmask  # (s,)
        xj = (c[:, j] - x @ lj) / l[j, j]
        sel = (idx == j).astype(c.dtype)[None, :]
        return x * (1.0 - sel) + xj[:, None] * sel

    return jax.lax.fori_loop(0, s, body, jnp.zeros_like(c))


def spd_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve SPD ``A X = B`` by Cholesky + two substitutions (small systems —
    the (r+1)×(r+1) Vandermonde normal equations)."""
    l = chol_unblocked(a)
    return trsolve_upper_t(l, trsolve_lower(l, b))
