"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: each kernel module's pytest suite
asserts ``allclose(kernel(...), ref(...))`` over shape/dtype sweeps. They are
deliberately written in the most obvious way possible — no tiling, no padding,
no cleverness — so a mismatch always indicts the kernel.
"""

import jax
import jax.numpy as jnp


def gram_ref(x: jax.Array, y: jax.Array):
    """Hessian ``H = XᵀX`` and gradient ``g = Xᵀy`` (paper eq. 2)."""
    return x.T @ x, x.T @ y


def vandermonde_ref(lams: jax.Array, r: int) -> jax.Array:
    """g×(r+1) observation matrix V: row s is ``[1, λ_s, …, λ_s^r]``
    (Algorithm 1 lines 3-4, monomial basis)."""
    return jnp.stack([lams**p for p in range(r + 1)], axis=1)


def polyfit_ref(lams: jax.Array, t: jax.Array, r: int) -> jax.Array:
    """Least-squares polynomial coefficients ``Θ = (VᵀV)⁻¹VᵀT``
    (Algorithm 1 lines 5-6 / paper eq. 4)."""
    v = vandermonde_ref(lams, r)
    h_lam = v.T @ v
    g_lam = v.T @ t
    return jnp.linalg.solve(h_lam, g_lam)


def polyeval_ref(theta: jax.Array, lams: jax.Array) -> jax.Array:
    """Evaluate the D fitted polynomials at a batch of λ's: ``P = B·Θ`` with
    ``B[t] = [1, λ_t, …, λ_t^r]`` — each row of P is an interpolated vec(L)."""
    r = theta.shape[0] - 1
    b = vandermonde_ref(lams, r)
    return b @ theta


def trisolve_ref(l: jax.Array, g: jax.Array) -> jax.Array:
    """Solve ``LLᵀθ = g`` by forward then backward substitution (paper §3.2)."""
    w = jax.scipy.linalg.solve_triangular(l, g, lower=True)
    return jax.scipy.linalg.solve_triangular(l.T, w, lower=False)


def chol_ref(h_mat: jax.Array, lam: jax.Array) -> jax.Array:
    """Exact Cholesky factor ``L = chol(H + λI)``."""
    hh = h_mat + lam * jnp.eye(h_mat.shape[0], dtype=h_mat.dtype)
    return jnp.linalg.cholesky(hh)


def vec_tri_ref(l: jax.Array) -> jax.Array:
    """Row-wise vectorization of the lower triangle — the canonical vec(·)
    ordering of the HLO interchange (rust mirrors it in vectorize::rowwise)."""
    h = l.shape[0]
    ii, jj = jnp.tril_indices(h)
    return l[ii, jj]


def unvec_tri_ref(v: jax.Array, h: int) -> jax.Array:
    """Inverse of :func:`vec_tri_ref`: scatter a length-D vector back into a
    lower-triangular h×h matrix."""
    ii, jj = jnp.tril_indices(h)
    return jnp.zeros((h, h), v.dtype).at[ii, jj].set(v)


def holdout_ref(xv: jax.Array, yv: jax.Array, theta: jax.Array):
    """Hold-out metrics for one coefficient vector: (RMSE, misclassification).

    The paper reports "hold-out error" for ±1-labelled 2-class problems
    (§6.1); we emit both the regression RMSE and the sign-misclassification
    rate so either can be plotted.
    """
    pred = xv @ theta
    rmse = jnp.sqrt(jnp.mean((pred - yv) ** 2))
    miscls = jnp.mean((jnp.sign(pred) != jnp.sign(yv)).astype(pred.dtype))
    return rmse, miscls
