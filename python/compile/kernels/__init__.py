"""Layer-1 Pallas kernels for piCholesky.

Four kernels cover the paper's compute hot spots (all run under
``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic custom-calls;
the BlockSpecs are still TPU-shaped, see DESIGN.md §Hardware-Adaptation):

- :mod:`gram`     — tiled ``H = XᵀX`` and ``g = Xᵀy`` (Figure 1's BLAS-3 step)
- :mod:`cholesky` — blocked right-looking Cholesky (the paper's dominant cost)
- :mod:`polyfit`  — Algorithm 1 lines 5-6, streaming the D axis through VMEM
- :mod:`polyeval` — dense-λ interpolation ``P = B·Θ`` (the O(r d²) payoff step)
- :mod:`trisolve` — blocked forward/backward substitution for ``LLᵀθ = g``

:mod:`ref` holds the pure-jnp oracles every kernel is pytest-verified against;
:mod:`blockops` holds the custom-call-free substitution primitives the kernels
share (LAPACK FFI custom-calls would not run on the rust PJRT client).
"""

from . import blockops, cholesky, gram, polyeval, polyfit, ref, trisolve  # noqa: F401
