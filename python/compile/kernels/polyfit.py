"""Pallas kernel for Algorithm 1 lines 5-6: ``Θ = (VᵀV)⁻¹ VᵀT``.

The paper's efficiency insight (§5) is that once the g exact factors are
vectorized into the g×D target matrix T, the fit is a pair of BLAS-3 calls.
D = h(h+1)/2 is enormous (≈134M for the paper's h=16384), so the kernel
streams T through VMEM in column tiles while the tiny (r+1)×g projector
``A = (VᵀV)⁻¹Vᵀ`` stays resident:

- ``A`` is formed once outside the kernel (an (r+1)×(r+1) solve — O(r³),
  negligible) and broadcast to every grid step.
- grid ``(D/TILE_D,)``; each step is one rank-g MXU matmul
  ``Θ[:, tile] = A · T[:, tile]`` — the kernel is bandwidth-bound, and the
  BlockSpec is exactly the HBM↔VMEM streaming schedule the paper implemented
  with cache-aligned memcpy on CPU.

VMEM per step: ``g·TILE_D + (r+1)·TILE_D + (r+1)·g`` floats ≈ 14 KB at the
defaults (g=4, r=2, TILE_D=512).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blockops
from ..shapes import TILE_D
from .ref import vandermonde_ref


def _proj_matmul_kernel(a_ref, t_ref, o_ref):
    """One D-tile: ``Θ_tile = A · T_tile`` (A fully VMEM-resident)."""
    o_ref[...] = jax.lax.dot_general(
        a_ref[...],
        t_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("tile_d",))
def proj_apply_tiled(a: jax.Array, t: jax.Array, tile_d: int = TILE_D) -> jax.Array:
    """Apply a small projector A ((r+1)×g) to T (g×D), D divisible by tile_d."""
    rp1, g = a.shape
    _, d = t.shape
    return pl.pallas_call(
        _proj_matmul_kernel,
        grid=(d // tile_d,),
        in_specs=[
            pl.BlockSpec((rp1, g), lambda i: (0, 0)),
            pl.BlockSpec((g, tile_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((rp1, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((rp1, d), t.dtype),
        interpret=True,
    )(a.astype(t.dtype), t)


def projector(lams: jax.Array, r: int) -> jax.Array:
    """The normal-equations projector ``A = (VᵀV)⁻¹Vᵀ`` ((r+1)×g).

    V is the leftmost r+1 columns of the g×g Vandermonde matrix at the sample
    λ's (Algorithm 1 lines 3-4). The paper notes V is well-conditioned for the
    monomial basis on its λ ranges. The (r+1)×(r+1) SPD normal equations are
    solved with the custom-call-free :func:`blockops.spd_solve` so the whole
    fit lowers to portable HLO.
    """
    v = vandermonde_ref(lams, r)
    h_lam = v.T @ v
    return blockops.spd_solve(h_lam, v.T)


def polyfit(lams: jax.Array, t: jax.Array, r: int, tile_d: int = TILE_D) -> jax.Array:
    """Public API: fit Θ ((r+1)×D) from sample points ``lams`` (g,) and targets
    T (g×D); arbitrary D (padded to the tile internally)."""
    g, d = t.shape
    a = projector(lams, r)
    pad = (-d) % tile_d
    tp = jnp.pad(t, ((0, 0), (0, pad))) if pad else t
    theta = proj_apply_tiled(a, tp, tile_d=tile_d)
    return theta[:, :d]
