"""Tiled Gram-matrix Pallas kernel: ``H = XᵀX`` and ``g = Xᵀy``.

This is the BLAS-3 "compute Hessian" step of the paper's Figure 1 pipeline
(O(n d²), the second-largest cost after the Cholesky sweep). The TPU mapping:

- grid ``(h/ti, h/tj, n/tk)`` with the reduction axis ``k`` innermost, so each
  (i, j) output block stays resident in VMEM across all k steps and is
  accumulated in fp32 by the MXU (``dot(xiᵀ, xj)`` per step).
- VMEM per step = ``ti·tk + tj·tk + ti·tj`` floats — for the default 128³
  tiling ≈ 0.19 MB, far under the ~16 MB VMEM budget, leaving room for the
  double-buffered prefetch the pipeline emitter inserts.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO while keeping this schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import pick_tile


def _gram_kernel(xi_ref, xj_ref, o_ref):
    """One (i, j, k) grid step: accumulate ``Xᵢᵀ·Xⱼ`` into the output block."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU contraction; accumulate in f32 regardless of input dtype.
    o_ref[...] += jax.lax.dot_general(
        xi_ref[...],
        xj_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


def _xty_kernel(x_ref, y_ref, o_ref):
    """One (i, k) grid step: accumulate ``Xᵢᵀ·y`` into the gradient block."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("tile_h", "tile_k"))
def gram_tiled(x: jax.Array, tile_h: int = 0, tile_k: int = 0) -> jax.Array:
    """``H = XᵀX`` for an exactly-tileable ``x`` (n and h divisible by tiles)."""
    n, h = x.shape
    ti = tile_h or pick_tile(h)
    tk = tile_k or pick_tile(n)
    acc = jnp.float32 if x.dtype != jnp.float64 else x.dtype
    out = pl.pallas_call(
        _gram_kernel,
        grid=(h // ti, h // ti, n // tk),
        in_specs=[
            pl.BlockSpec((tk, ti), lambda i, j, k: (k, i)),
            pl.BlockSpec((tk, ti), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((ti, ti), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, h), acc),
        interpret=True,
    )(x, x)
    return out.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("tile_h", "tile_k"))
def xty_tiled(x: jax.Array, y: jax.Array, tile_h: int = 0, tile_k: int = 0) -> jax.Array:
    """``g = Xᵀy`` for exactly-tileable inputs."""
    n, h = x.shape
    ti = tile_h or pick_tile(h)
    tk = tile_k or pick_tile(n)
    acc = jnp.float32 if x.dtype != jnp.float64 else x.dtype
    out = pl.pallas_call(
        _xty_kernel,
        grid=(h // ti, n // tk),
        in_specs=[
            pl.BlockSpec((tk, ti), lambda i, k: (k, i)),
            pl.BlockSpec((tk, 1), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((ti, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, 1), acc),
        interpret=True,
    )(x, y.reshape(n, 1))
    return out.reshape(h).astype(x.dtype)


def _pad2(x: jax.Array, mr: int, mc: int) -> jax.Array:
    """Zero-pad a matrix so both dims are tile multiples (zeros do not perturb
    XᵀX / Xᵀy — padded rows/cols contribute exact zeros)."""
    n, h = x.shape
    pr = (-n) % mr
    pc = (-h) % mc
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def gram(x: jax.Array, y: jax.Array, tile_h: int = 256, tile_k: int = 512):
    """Public API: ``(H, g) = (XᵀX, Xᵀy)`` for arbitrary shapes.

    Pads n and h up to tile multiples, runs the tiled kernels, slices back.

    Default tiles are 256×512 (VMEM per step ≈ 1.3 MB — still ≪ 16 MB): the
    interpret-mode pipeline pays a fixed cost per grid step, and the §Perf
    pass measured 128³ tiling losing ~3× to grid overhead at h=256. On a
    real TPU the sweet spot would be re-measured with the MXU profiler; the
    BlockSpec structure is unchanged.
    """
    n, h = x.shape
    # don't pad a dimension past its own pow2 envelope just to honour the
    # requested tile (h=64 with tile 256 would 4× the flops for nothing)
    def envelope(dim: int) -> int:
        p = 8
        while p < dim:
            p *= 2
        return p

    tile_h = min(tile_h, envelope(h))
    tile_k = min(tile_k, envelope(n))
    xp = _pad2(x, tile_k, tile_h)
    yp = jnp.pad(y, (0, (-n) % tile_k))
    hp = gram_tiled(xp, tile_h=tile_h, tile_k=tile_k)
    gp = xty_tiled(xp, yp, tile_h=tile_h, tile_k=tile_k)
    return hp[:h, :h], gp[:h]
