"""Blocked Cholesky as a Pallas kernel — the paper's dominant compute.

Every fold×λ pair costs one ``chol(H + λI)`` (paper Figure 1); this kernel is
that operation, written right-looking so nearly all flops are the rank-bs
trailing SYRK update the MXU eats:

1. *factor* the bs×bs diagonal block (masked column recurrence — VPU work);
2. *TRSM* the panel below it: ``X·L_kkᵀ = A_panel`` (bs² · h MXU-adjacent);
3. *SYRK* the trailing matrix: ``A₂₂ −= X Xᵀ`` (the O(h·bs²) → O(h³/3) bulk,
   one big ``dot`` per step).

The whole matrix stays VMEM-resident (h ≤ 512 ⇒ ≤ 1 MB fp32); the kernel is
one program with a ``fori_loop`` over the h/bs block columns — the recurrence
is inherently sequential, parallelism lives inside each step's dense ops.

No LAPACK custom-calls anywhere (see :mod:`blockops`): the artifact runs on
any PJRT backend, including the rust CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import blockops

#: Default panel width: wide enough that the SYRK step dominates, narrow
#: enough that the unblocked diagonal factorization stays cheap.
CHOL_BS = 32


def _make_kernel(h: int, bs: int):
    nb = h // bs

    def kernel(a_ref, l_ref):
        rows = jax.lax.broadcasted_iota(jnp.int32, (h, 1), 0)

        def col_step(k, a):
            off = k * bs
            # 1. diagonal block factor
            d = jax.lax.dynamic_slice(a, (off, off), (bs, bs))
            ld = blockops.chol_unblocked(d)
            a = jax.lax.dynamic_update_slice(a, ld, (off, off))

            # 2. panel TRSM: rows below the diagonal block, this block column
            panel = jax.lax.dynamic_slice(a, (0, off), (h, bs))
            below = (rows >= off + bs).astype(a.dtype)  # (h,1)
            x = blockops.trsolve_right_lt(panel * below, ld)
            panel = panel * (1.0 - below) + x * below
            a = jax.lax.dynamic_update_slice(a, panel, (0, off))

            # 3. trailing SYRK: A₂₂ −= X Xᵀ (only rows/cols ≥ off+bs)
            xm = x * below
            upd = jax.lax.dot_general(
                xm, xm, (((1,), (1,)), ((), ())), preferred_element_type=a.dtype
            )
            mask2 = below * below.reshape(1, h)
            return a - upd * mask2

        a_final = jax.lax.fori_loop(0, nb, col_step, a_ref[...])
        l_ref[...] = jnp.tril(a_final)

    return kernel


@functools.partial(jax.jit, static_argnames=("bs",))
def chol_blocked(a: jax.Array, bs: int = CHOL_BS) -> jax.Array:
    """Cholesky factor of an SPD matrix with h divisible by bs."""
    h = a.shape[0]
    return pl.pallas_call(
        _make_kernel(h, bs),
        out_shape=jax.ShapeDtypeStruct((h, h), a.dtype),
        interpret=True,
    )(a)


def cholesky(a: jax.Array, bs: int = CHOL_BS) -> jax.Array:
    """Public API: Cholesky for arbitrary h (identity-padded to a bs multiple;
    the padding block factors to the identity and never touches real data)."""
    h = a.shape[0]
    pad = (-h) % bs
    if pad:
        eye_tail = jnp.diag(
            jnp.pad(jnp.zeros(h, a.dtype), (0, pad), constant_values=1.0)
        )
        a = jnp.pad(a, ((0, pad), (0, pad))) + eye_tail
    l = chol_blocked(a, bs=bs)
    return l[:h, :h] if pad else l
