"""Layer-2 JAX graphs: the piCholesky pipeline composed from the L1 kernels.

Each public function here becomes one AOT artifact per shape config (see
``shapes.CONFIGS``). The rust coordinator sequences them:

    gram ─► cholvec ─► polyfit ─► sweep ──► argmin λ
                 │                  ▲
                 └── (baselines) chol_solve ─► holdout

Only jnp ops and the Pallas kernels appear — everything lowers to a single
HLO module per function with static shapes, which is what the xla 0.1.6 crate
can compile and run on the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import cholesky as cholesky_k
from .kernels import gram as gram_k
from .kernels import polyeval as polyeval_k
from .kernels import polyfit as polyfit_k
from .kernels import trisolve as trisolve_k
from .shapes import TILE_D, pad_to


def gram_fn(x: jax.Array, y: jax.Array):
    """Artifact ``gram``: Hessian and gradient from the design matrix.

    (X[n,h], y[n]) → (H[h,h], g[h]). The O(nd²) step of Figure 1.
    """
    return gram_k.gram(x, y)


def cholvec_fn(h_mat: jax.Array, lams: jax.Array):
    """Artifact ``cholvec``: the g exact factors, vectorized (Algorithm 1
    lines 1-2).

    (H[h,h], λ[g]) → T[g, h²] — **full-matrix** vectorization (paper §5): a
    plain reshape. The triangle gather (row-wise/recursive orderings) costs
    ~10× the factorization itself on the CPU PJRT backend (§Perf), so the
    HLO path takes Table 1's aligned-copy/2×-flops trade-off.
    """
    h = h_mat.shape[0]
    eye = jnp.eye(h, dtype=h_mat.dtype)

    def one(lam):
        l = cholesky_k.cholesky(h_mat + lam * eye)
        return l.reshape(h * h)

    return jax.lax.map(one, lams)


def polyfit_fn(lams: jax.Array, t: jax.Array, r: int):
    """Artifact ``polyfit``: Θ = (VᵀV)⁻¹VᵀT (Algorithm 1 lines 3-6).

    (λ[g], T[g,D]) → Θ[(r+1), D_pad]. The output keeps the tile padding so the
    downstream sweep/polyeval artifacts can stream it without re-padding.
    """
    g, d = t.shape
    a = polyfit_k.projector(lams, r)
    tp = jnp.pad(t, ((0, 0), (0, pad_to(d, TILE_D) - d)))
    return polyfit_k.proj_apply_tiled(a, tp)


def polyeval_fn(theta: jax.Array, lams_m: jax.Array, d: int):
    """Artifact ``polyeval``: interpolated vec(L) rows at the dense grid.

    (Θ[(r+1), D_pad], λ[m]) → P[m, d] (padding sliced off; d = h² in the
    full-matrix layout).
    """
    from .kernels.ref import vandermonde_ref

    b = vandermonde_ref(lams_m, theta.shape[0] - 1)
    p = polyeval_k.eval_tiled(b, theta)
    return p[:, :d]


def solve_one_fn(l: jax.Array, g_vec: jax.Array):
    """Solve LLᵀθ = g via the blocked-substitution kernel."""
    return trisolve_k.trisolve(l, g_vec)


def holdout_fn(xv: jax.Array, yv: jax.Array, theta: jax.Array):
    """Artifact ``holdout``: (RMSE, misclassification) of one θ on the
    validation fold."""
    pred = xv @ theta
    rmse = jnp.sqrt(jnp.mean((pred - yv) ** 2))
    miscls = jnp.mean((jnp.sign(pred) != jnp.sign(yv)).astype(pred.dtype))
    return jnp.stack([rmse, miscls])


def chol_solve_fn(h_mat: jax.Array, lam: jax.Array, g_vec: jax.Array):
    """Artifact ``chol_solve``: one exact baseline solve,
    θ = (H + λI)⁻¹ g via Cholesky + blocked substitution (paper §3.2)."""
    h = h_mat.shape[0]
    l = cholesky_k.cholesky(h_mat + lam * jnp.eye(h, dtype=h_mat.dtype))
    return trisolve_k.trisolve(l, g_vec)


def sweep_fn(
    theta: jax.Array,
    lams_m: jax.Array,
    g_vec: jax.Array,
    xv: jax.Array,
    yv: jax.Array,
):
    """Artifact ``sweep``: the entire piCholesky inner loop for one fold in a
    single HLO module — the L2 fusion that keeps python (and per-λ dispatch
    overhead) off the request path.

    (Θ[(r+1),D_pad], λ[m], g[h], Xv[nv,h], yv[nv]) → errs[m, 2]:
      1. P = B·Θ            (polyeval kernel, one pass over D for all m λ's)
      2. L_t = tril(P_t.reshape(h,h))  (full-matrix unvec: free; tril clamps
                                        the fitted-zero upper triangle)
      3. θ_t = LLᵀ \\ g      (trisolve kernel)
      4. errs_t = holdout(Xv, yv, θ_t)
    """
    h = g_vec.shape[0]
    d = h * h
    p = polyeval_fn(theta, lams_m, d)

    def per_lambda(p_row):
        l = jnp.tril(p_row.reshape(h, h))
        th = trisolve_k.trisolve(l, g_vec)
        return holdout_fn(xv, yv, th)

    return jax.lax.map(per_lambda, p)


def exact_sweep_fn(
    h_mat: jax.Array,
    lams_m: jax.Array,
    g_vec: jax.Array,
    xv: jax.Array,
    yv: jax.Array,
):
    """Baseline counterpart of :func:`sweep_fn`: exact Cholesky at every grid
    point (the paper's ``Chol`` algorithm) fused into one HLO module."""
    def per_lambda(lam):
        th = chol_solve_fn(h_mat, lam, g_vec)
        return holdout_fn(xv, yv, th)

    return jax.lax.map(per_lambda, lams_m)


# ---------------------------------------------------------------------------
# Convenience jitted wrappers for the python test-suite (not lowered to
# artifacts; the artifacts are produced shape-by-shape in aot.py).
# ---------------------------------------------------------------------------

pichol_fit = jax.jit(
    lambda h_mat, lams, r: polyfit_fn(lams, cholvec_fn(h_mat, lams), r),
    static_argnames=("r",),
)


@functools.partial(jax.jit, static_argnames=("r",))
def pichol_sweep(h_mat, lams_g, lams_m, g_vec, xv, yv, *, r=2):
    t = cholvec_fn(h_mat, lams_g)
    theta = polyfit_fn(lams_g, t, r)
    return sweep_fn(theta, lams_m, g_vec, xv, yv)
