"""AOT lowering: JAX graphs → HLO text artifacts + manifest.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO **text**, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). Every function is
lowered with ``return_tuple=True`` so the rust side always unwraps a tuple.

``manifest.json`` records, for every artifact, the exact parameter/result
shapes it was lowered at; the rust runtime validates its literals against the
manifest before execution.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .shapes import ARTIFACTS, CONFIGS, PiCholConfig

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def lowerings(cfg: PiCholConfig):
    """(name → (fn, example_arg_specs)) for one shape config."""
    h, n, nv, g, r, m = cfg.h, cfg.n, cfg.n_val, cfg.g, cfg.r, cfg.m
    d, dp = cfg.d_vec, cfg.d_pad
    return {
        "gram": (
            lambda x, y: model.gram_fn(x, y),
            (_spec(n, h), _spec(n)),
        ),
        "cholvec": (
            lambda hm, ls: (model.cholvec_fn(hm, ls),),
            (_spec(h, h), _spec(g)),
        ),
        "polyfit": (
            lambda ls, t: (model.polyfit_fn(ls, t, r),),
            (_spec(g), _spec(g, d)),
        ),
        "polyeval": (
            lambda th, ls: (model.polyeval_fn(th, ls, d),),
            (_spec(r + 1, dp), _spec(m)),
        ),
        "sweep": (
            lambda th, ls, gv, xv, yv: (model.sweep_fn(th, ls, gv, xv, yv),),
            (_spec(r + 1, dp), _spec(m), _spec(h), _spec(nv, h), _spec(nv)),
        ),
        "chol_solve": (
            lambda hm, lam, gv: (model.chol_solve_fn(hm, lam, gv),),
            (_spec(h, h), _spec(), _spec(h)),
        ),
        "holdout": (
            lambda xv, yv, th: (model.holdout_fn(xv, yv, th),),
            (_spec(nv, h), _spec(nv), _spec(h)),
        ),
        "exact_sweep": (
            lambda hm, ls, gv, xv, yv: (model.exact_sweep_fn(hm, ls, gv, xv, yv),),
            (_spec(h, h), _spec(m), _spec(h), _spec(nv, h), _spec(nv)),
        ),
    }


def lower_one(name, fn, specs, out_dir, tag):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{name}_{tag}.hlo.txt"
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    return {
        "file": fname,
        "params": [list(s.shape) for s in specs],
        "dtype": "f32",
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        "bytes": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--configs",
        default="",
        help="comma-separated h values to lower (default: all in shapes.CONFIGS)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = {int(x) for x in args.configs.split(",") if x}
    manifest = {"artifacts": ARTIFACTS + ["exact_sweep"], "configs": []}
    for cfg in CONFIGS:
        if only and cfg.h not in only:
            continue
        entry = cfg.manifest_entry()
        entry["files"] = {}
        for name, (fn, specs) in lowerings(cfg).items():
            info = lower_one(name, fn, specs, args.out_dir, cfg.tag())
            entry["files"][name] = info
            print(f"  {info['file']:42s} {info['bytes']:>9d} bytes")
        manifest["configs"].append(entry)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['configs'])} configs to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
